//! The Partitioned Global Address Space.
//!
//! "Each node in a PGAS cluster has one partition of the global address
//! space" (paper §II-A3) — in Shoal the partition granularity is the kernel:
//! every kernel owns a `Segment` of shared memory that remote kernels can
//! target with Long AMs and *get* requests. A `GlobalAddress` names a byte
//! offset within a specific kernel's partition.
//!
//! On FPGAs the segments live in off-chip DRAM behind the AXI DataMover; in
//! software they are the buffers the handler thread serves. Either way the
//! owning kernel accesses its partition directly (local access), while
//! remote kernels go through AMs (remote access) — the PGAS local/remote
//! distinction.
//!
//! Concurrency: a segment has exactly two writers — the owning kernel (local
//! stores) and its runtime component (handler thread / GAScore DMA writes) —
//! so an `RwLock` around the buffer is uncontended in steady state. The
//! allocator hands out non-overlapping ranges; allocation is coarse
//! (application setup time), not hot-path.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::am::types::AtomicOp;
use crate::collectives::{self, Lane, ReduceOp};
use crate::error::{Error, Result};

/// A location in the global address space: byte `offset` within kernel
/// `kernel`'s partition.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GlobalAddress {
    pub kernel: u16,
    pub offset: u64,
}

impl GlobalAddress {
    pub fn new(kernel: u16, offset: u64) -> Self {
        Self { kernel, offset }
    }

    /// Displace within the same partition. Overflow is an error, not a
    /// silent `u64` wrap — a wrapped offset would alias the bottom of the
    /// partition and corrupt unrelated data on the next put.
    pub fn plus(self, bytes: u64) -> Result<Self> {
        let offset = self.offset.checked_add(bytes).ok_or_else(|| {
            Error::BadDescriptor(format!(
                "global address overflow: kernel {} offset {:#x} + {:#x} exceeds u64",
                self.kernel, self.offset, bytes
            ))
        })?;
        Ok(Self { kernel: self.kernel, offset })
    }

    /// Displace with wraparound — only for address-arithmetic call sites
    /// that bound the result themselves.
    pub fn wrapping_plus(self, bytes: u64) -> Self {
        Self { kernel: self.kernel, offset: self.offset.wrapping_add(bytes) }
    }
}

/// One kernel's partition of the global address space.
#[derive(Clone)]
pub struct Segment {
    inner: Arc<SegmentInner>,
}

struct SegmentInner {
    buf: RwLock<Box<[u8]>>,
    /// Raw pointer to the buffer's heap allocation, captured at construction
    /// (a `Box<[u8]>`'s allocation never moves). Lets the atomic ops build
    /// `AtomicU64` views onto segment words while holding only the *read*
    /// lock — non-atomic writers always take the write lock, so the two
    /// access modes never overlap on the same bytes.
    base: *mut u8,
    /// Free-list allocator state: offset → length of free block.
    alloc: RwLock<Allocator>,
    size: usize,
}

// SAFETY: `base` is only dereferenced through the atomic-view discipline
// documented on `atomic_view` (under the buf lock), which serializes it
// against the RwLock-guarded accessors. The pointer itself is immutable.
unsafe impl Send for SegmentInner {}
// SAFETY: as for `Send` — shared references only reach `base` through the
// same lock-serialized atomic views, so aliasing across threads is sound.
unsafe impl Sync for SegmentInner {}

impl Segment {
    /// Create a zero-initialized segment of `size` bytes.
    pub fn new(size: usize) -> Segment {
        let mut buf = vec![0u8; size].into_boxed_slice();
        let base = buf.as_mut_ptr();
        Segment {
            inner: Arc::new(SegmentInner {
                buf: RwLock::new(buf),
                base,
                alloc: RwLock::new(Allocator::new(size)),
                size,
            }),
        }
    }

    pub fn size(&self) -> usize {
        self.inner.size
    }

    fn check(&self, offset: u64, len: usize) -> Result<()> {
        if offset as usize + len > self.inner.size {
            return Err(Error::SegmentOutOfBounds { offset, len, size: self.inner.size });
        }
        Ok(())
    }

    /// Validate a range without touching it — lets callers fail an operation
    /// up front, before any part of it has been issued.
    pub fn check_range(&self, offset: u64, len: usize) -> Result<()> {
        self.check(offset, len)
    }

    /// Copy bytes out of the segment.
    pub fn read(&self, offset: u64, len: usize) -> Result<Vec<u8>> {
        self.check(offset, len)?;
        let buf = self.inner.buf.read().unwrap();
        Ok(buf[offset as usize..offset as usize + len].to_vec())
    }

    /// Copy bytes out into a caller-provided buffer (no allocation).
    pub fn read_into(&self, offset: u64, out: &mut [u8]) -> Result<()> {
        self.check(offset, out.len())?;
        let buf = self.inner.buf.read().unwrap();
        out.copy_from_slice(&buf[offset as usize..offset as usize + out.len()]);
        Ok(())
    }

    /// Write bytes into the segment.
    pub fn write(&self, offset: u64, data: &[u8]) -> Result<()> {
        self.check(offset, data.len())?;
        let mut buf = self.inner.buf.write().unwrap();
        buf[offset as usize..offset as usize + data.len()].copy_from_slice(data);
        Ok(())
    }

    /// Copy `len` bytes from `src` (at `src_off`) into this segment (at
    /// `dst_off`) without an intermediate buffer — the intra-node one-sided
    /// fast path: a local put/get is a single segment-to-segment memcpy.
    ///
    /// Deadlock-safe for any aliasing: a same-segment copy takes one write
    /// lock and uses `copy_within`; distinct segments are locked in a global
    /// (address) order so two kernels copying toward each other concurrently
    /// cannot deadlock.
    pub fn copy_from(&self, dst_off: u64, src: &Segment, src_off: u64, len: usize) -> Result<()> {
        self.check(dst_off, len)?;
        src.check(src_off, len)?;
        if Arc::ptr_eq(&self.inner, &src.inner) {
            let mut buf = self.inner.buf.write().unwrap();
            buf.copy_within(src_off as usize..src_off as usize + len, dst_off as usize);
            return Ok(());
        }
        let copy = |dst: &mut [u8], srcb: &[u8]| {
            dst[dst_off as usize..dst_off as usize + len]
                .copy_from_slice(&srcb[src_off as usize..src_off as usize + len]);
        };
        if Arc::as_ptr(&self.inner) as usize <= Arc::as_ptr(&src.inner) as usize {
            let mut d = self.inner.buf.write().unwrap();
            let s = src.inner.buf.read().unwrap();
            copy(&mut d, &s);
        } else {
            let s = src.inner.buf.read().unwrap();
            let mut d = self.inner.buf.write().unwrap();
            copy(&mut d, &s);
        }
        Ok(())
    }

    /// Strided scatter: block `i` of `block_len` bytes from `data` lands at
    /// `offset + i*stride`.
    pub fn write_strided(&self, offset: u64, stride: u32, block_len: u32, data: &[u8]) -> Result<()> {
        if block_len == 0 {
            return Err(Error::BadDescriptor("strided write with block_len=0".into()));
        }
        if data.len() % block_len as usize != 0 {
            return Err(Error::BadDescriptor(format!(
                "payload {} not a multiple of block {}",
                data.len(),
                block_len
            )));
        }
        let nblocks = data.len() / block_len as usize;
        let span = if nblocks == 0 {
            0
        } else {
            (nblocks - 1) * stride as usize + block_len as usize
        };
        self.check(offset, span)?;
        let mut buf = self.inner.buf.write().unwrap();
        for i in 0..nblocks {
            let dst = offset as usize + i * stride as usize;
            let src = i * block_len as usize;
            buf[dst..dst + block_len as usize]
                .copy_from_slice(&data[src..src + block_len as usize]);
        }
        Ok(())
    }

    /// Strided gather: the inverse of `write_strided`.
    pub fn read_strided(&self, offset: u64, stride: u32, block_len: u32, nblocks: u32) -> Result<Vec<u8>> {
        if block_len == 0 {
            return Err(Error::BadDescriptor("strided read with block_len=0".into()));
        }
        let span = if nblocks == 0 {
            0
        } else {
            (nblocks as usize - 1) * stride as usize + block_len as usize
        };
        self.check(offset, span)?;
        let buf = self.inner.buf.read().unwrap();
        let mut out = Vec::with_capacity(block_len as usize * nblocks as usize);
        for i in 0..nblocks as usize {
            let src = offset as usize + i * stride as usize;
            out.extend_from_slice(&buf[src..src + block_len as usize]);
        }
        Ok(out)
    }

    /// Vectored scatter over (addr, len) extents.
    pub fn write_vectored(&self, entries: &[(u64, u32)], data: &[u8]) -> Result<()> {
        let total: u64 = entries.iter().map(|(_, l)| *l as u64).sum();
        if total != data.len() as u64 {
            return Err(Error::BadDescriptor(format!(
                "vectored extents sum {total} ≠ payload {}",
                data.len()
            )));
        }
        for (addr, len) in entries {
            self.check(*addr, *len as usize)?;
        }
        let mut buf = self.inner.buf.write().unwrap();
        let mut cursor = 0usize;
        for (addr, len) in entries {
            let len = *len as usize;
            buf[*addr as usize..*addr as usize + len]
                .copy_from_slice(&data[cursor..cursor + len]);
            cursor += len;
        }
        Ok(())
    }

    /// Vectored gather.
    pub fn read_vectored(&self, entries: &[(u64, u32)]) -> Result<Vec<u8>> {
        for (addr, len) in entries {
            self.check(*addr, *len as usize)?;
        }
        let buf = self.inner.buf.read().unwrap();
        let total: usize = entries.iter().map(|(_, l)| *l as usize).sum();
        let mut out = Vec::with_capacity(total);
        for (addr, len) in entries {
            out.extend_from_slice(&buf[*addr as usize..*addr as usize + *len as usize]);
        }
        Ok(out)
    }

    // -- typed helpers ------------------------------------------------------

    /// Write a slice of f32 values (little-endian) at a byte offset.
    pub fn write_f32(&self, offset: u64, vals: &[f32]) -> Result<()> {
        self.check(offset, vals.len() * 4)?;
        let mut buf = self.inner.buf.write().unwrap();
        let base = offset as usize;
        for (i, v) in vals.iter().enumerate() {
            buf[base + 4 * i..base + 4 * i + 4].copy_from_slice(&v.to_le_bytes());
        }
        Ok(())
    }

    /// Read `count` f32 values from a byte offset.
    pub fn read_f32(&self, offset: u64, count: usize) -> Result<Vec<f32>> {
        let mut out = vec![0f32; count];
        self.read_f32_into(offset, &mut out)?;
        Ok(out)
    }

    /// Read f32 values into a caller-provided buffer (no allocation — the
    /// Jacobi worker hot loop uses this for halo+tile assembly).
    pub fn read_f32_into(&self, offset: u64, out: &mut [f32]) -> Result<()> {
        self.check(offset, out.len() * 4)?;
        let buf = self.inner.buf.read().unwrap();
        let base = offset as usize;
        for (i, v) in out.iter_mut().enumerate() {
            *v = f32::from_le_bytes(buf[base + 4 * i..base + 4 * i + 4].try_into().unwrap());
        }
        Ok(())
    }

    // -- atomics ------------------------------------------------------------

    /// An `AtomicU64` view of the 8-byte word at `offset`, or `None` when
    /// the word is not naturally aligned in the heap allocation.
    ///
    /// SAFETY CONTRACT (private): the caller must hold the `buf` lock (read
    /// suffices) for the lifetime of the returned reference. Non-atomic
    /// accessors mutate only under the write lock, so a read-locked atomic
    /// view can race only with *other atomic views* — which is exactly what
    /// `AtomicU64` makes sound.
    fn atomic_view(&self, offset: u64) -> Option<&AtomicU64> {
        // SAFETY: pointer arithmetic stays inside the boxed allocation —
        // the caller ran check(), so `offset + 8 <= size`, and `base` is
        // non-null for any in-bounds offset (check() rejects everything
        // when size == 0).
        let p = unsafe { self.inner.base.add(offset as usize) };
        if (p as usize) % std::mem::align_of::<AtomicU64>() != 0 {
            return None;
        }
        // SAFETY: in-bounds (caller ran check), aligned (just tested), and
        // data-race-free per the contract above.
        Some(unsafe { &*(p as *const AtomicU64) })
    }

    /// Atomically read-modify-write the 8-byte word at `offset` with a
    /// scalar [`AtomicOp`]; returns the old value. Values are read and
    /// written little-endian, matching the wire/word order everywhere else
    /// in the segment.
    ///
    /// Lock-free when the word is naturally aligned (an `AtomicU64` RMW
    /// under the read lock, so concurrent atomics from the fast path and
    /// the AM engine don't serialize behind each other); misaligned words
    /// fall back to a locked RMW under the write lock. A given offset
    /// always takes the same path, so mixed-path races cannot happen.
    pub fn atomic_rmw(&self, offset: u64, op: AtomicOp, operand: u64, operand2: u64) -> Result<u64> {
        if op.is_accumulate() {
            return Err(Error::BadDescriptor(format!(
                "accumulate op {op} on the scalar atomic path"
            )));
        }
        self.check(offset, 8)?;
        let apply = |old: u64| -> u64 {
            match op {
                AtomicOp::FaaAdd => old.wrapping_add(operand),
                AtomicOp::FaaMin => old.min(operand),
                AtomicOp::FaaMax => old.max(operand),
                AtomicOp::FaaAnd => old & operand,
                AtomicOp::FaaOr => old | operand,
                AtomicOp::FaaXor => old ^ operand,
                AtomicOp::Swap => operand,
                AtomicOp::Cas => {
                    if old == operand {
                        operand2
                    } else {
                        old
                    }
                }
                AtomicOp::AccSum | AtomicOp::AccMin | AtomicOp::AccMax => old,
            }
        };
        let guard = self.inner.buf.read().unwrap();
        if let Some(a) = self.atomic_view(offset) {
            let raw = a
                .fetch_update(Ordering::AcqRel, Ordering::Acquire, |cur| {
                    Some(apply(u64::from_le(cur)).to_le())
                })
                .expect("fetch_update closure always returns Some");
            drop(guard);
            return Ok(u64::from_le(raw));
        }
        drop(guard);
        let mut buf = self.inner.buf.write().unwrap();
        let i = offset as usize;
        let old = u64::from_le_bytes(buf[i..i + 8].try_into().unwrap());
        let new = apply(old);
        buf[i..i + 8].copy_from_slice(&new.to_le_bytes());
        Ok(old)
    }

    /// Element-wise atomic accumulate: fold `data`'s 8-byte lanes into the
    /// segment starting at `offset` with `op` over `lane`-typed elements.
    ///
    /// U64 lanes on aligned words run lock-free (one `AtomicU64` RMW per
    /// lane under the read lock). F64 lanes — and misaligned U64 ranges —
    /// take the write lock: IEEE arithmetic has no hardware RMW, and the
    /// exclusive lock makes the read-modify-write of every lane atomic
    /// with respect to all other segment writers.
    pub fn accumulate(&self, offset: u64, op: ReduceOp, lane: Lane, data: &[u8]) -> Result<()> {
        if data.is_empty() || data.len() % 8 != 0 {
            return Err(Error::BadDescriptor(format!(
                "accumulate payload must be a non-empty multiple of 8 B, got {}",
                data.len()
            )));
        }
        self.check(offset, data.len())?;
        if lane == Lane::U64 {
            let guard = self.inner.buf.read().unwrap();
            if self.atomic_view(offset).is_some() {
                // offset is aligned, so every 8-byte lane after it is too.
                for (k, chunk) in data.chunks_exact(8).enumerate() {
                    let v = u64::from_le_bytes(chunk.try_into().unwrap());
                    let a = self
                        .atomic_view(offset + 8 * k as u64)
                        .expect("aligned base implies aligned lanes");
                    a.fetch_update(Ordering::AcqRel, Ordering::Acquire, |cur| {
                        let old = u64::from_le(cur);
                        let new = match op {
                            ReduceOp::Sum => old.wrapping_add(v),
                            ReduceOp::Min => old.min(v),
                            ReduceOp::Max => old.max(v),
                        };
                        Some(new.to_le())
                    })
                    .expect("fetch_update closure always returns Some");
                }
                return Ok(());
            }
            drop(guard);
        }
        // F64 lanes and misaligned U64 ranges: exclusive-lock fold.
        let mut buf = self.inner.buf.write().unwrap();
        let i = offset as usize;
        collectives::combine(op, lane, &mut buf[i..i + data.len()], data)
    }

    // -- allocation ---------------------------------------------------------

    /// Allocate `len` bytes (8-byte aligned); returns the offset.
    pub fn alloc(&self, len: usize) -> Result<u64> {
        self.inner.alloc.write().unwrap().alloc(len)
    }

    /// Free a previously allocated block.
    pub fn free(&self, offset: u64) -> Result<()> {
        self.inner.alloc.write().unwrap().free(offset)
    }

    /// Bytes currently allocated.
    pub fn allocated_bytes(&self) -> usize {
        self.inner.alloc.read().unwrap().allocated
    }
}

/// First-fit free-list allocator with coalescing.
struct Allocator {
    /// offset → len of free blocks.
    free: BTreeMap<u64, usize>,
    /// offset → len of live allocations.
    live: BTreeMap<u64, usize>,
    allocated: usize,
}

const ALIGN: usize = 8;

impl Allocator {
    fn new(size: usize) -> Self {
        let mut free = BTreeMap::new();
        if size > 0 {
            free.insert(0, size);
        }
        Self { free, live: BTreeMap::new(), allocated: 0 }
    }

    fn alloc(&mut self, len: usize) -> Result<u64> {
        if len == 0 {
            return Err(Error::OutOfMemory(0));
        }
        let len = len.div_ceil(ALIGN) * ALIGN;
        let slot = self
            .free
            .iter()
            .find(|(_, &flen)| flen >= len)
            .map(|(&off, &flen)| (off, flen));
        match slot {
            Some((off, flen)) => {
                self.free.remove(&off);
                if flen > len {
                    self.free.insert(off + len as u64, flen - len);
                }
                self.live.insert(off, len);
                self.allocated += len;
                Ok(off)
            }
            None => Err(Error::OutOfMemory(len)),
        }
    }

    fn free(&mut self, offset: u64) -> Result<()> {
        let len = self
            .live
            .remove(&offset)
            .ok_or_else(|| Error::BadDescriptor(format!("free of unallocated offset {offset}")))?;
        self.allocated -= len;
        // Insert and coalesce with neighbours.
        let mut start = offset;
        let mut size = len;
        if let Some((&poff, &plen)) = self.free.range(..offset).next_back() {
            if poff + plen as u64 == offset {
                self.free.remove(&poff);
                start = poff;
                size += plen;
            }
        }
        if let Some(&nlen) = self.free.get(&(offset + len as u64)) {
            self.free.remove(&(offset + len as u64));
            size += nlen;
        }
        self.free.insert(start, size);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_address_plus_is_checked() {
        let a = GlobalAddress::new(3, 100);
        let b = a.plus(28).unwrap();
        assert_eq!(b, GlobalAddress::new(3, 128));
        // Regression: `plus` used to wrap silently on u64 overflow.
        let near_top = GlobalAddress::new(3, u64::MAX - 4);
        assert!(matches!(near_top.plus(5), Err(Error::BadDescriptor(_))));
        assert_eq!(near_top.plus(4).unwrap().offset, u64::MAX);
        assert_eq!(near_top.wrapping_plus(5).offset, 0);
    }

    #[test]
    fn read_write_roundtrip() {
        let s = Segment::new(1024);
        s.write(100, &[1, 2, 3]).unwrap();
        assert_eq!(s.read(100, 3).unwrap(), vec![1, 2, 3]);
        assert_eq!(s.read(99, 1).unwrap(), vec![0]);
    }

    #[test]
    fn bounds_checked() {
        let s = Segment::new(16);
        assert!(matches!(s.write(10, &[0; 7]), Err(Error::SegmentOutOfBounds { .. })));
        assert!(s.read(16, 1).is_err());
        assert!(s.write(0, &[0; 16]).is_ok());
    }

    #[test]
    fn f32_typed_access() {
        let s = Segment::new(64);
        s.write_f32(8, &[1.5, -2.25, 3.0]).unwrap();
        assert_eq!(s.read_f32(8, 3).unwrap(), vec![1.5, -2.25, 3.0]);
    }

    #[test]
    fn copy_from_between_and_within_segments() {
        let a = Segment::new(256);
        let b = Segment::new(256);
        a.write(16, &[1, 2, 3, 4]).unwrap();
        b.copy_from(100, &a, 16, 4).unwrap();
        assert_eq!(b.read(100, 4).unwrap(), vec![1, 2, 3, 4]);
        // Same segment (the local self-put): one write lock, no deadlock.
        let c = a.clone();
        a.copy_from(200, &c, 16, 4).unwrap();
        assert_eq!(a.read(200, 4).unwrap(), vec![1, 2, 3, 4]);
        // Bounds still checked on both sides.
        assert!(b.copy_from(254, &a, 0, 4).is_err());
        assert!(b.copy_from(0, &a, 254, 4).is_err());
    }

    #[test]
    fn concurrent_opposing_copies_do_not_deadlock() {
        let a = Segment::new(4096);
        let b = Segment::new(4096);
        let (a2, b2) = (a.clone(), b.clone());
        let t = std::thread::spawn(move || {
            for _ in 0..2000 {
                a2.copy_from(0, &b2, 0, 1024).unwrap();
            }
        });
        for _ in 0..2000 {
            b.copy_from(0, &a, 0, 1024).unwrap();
        }
        t.join().unwrap();
    }

    #[test]
    fn strided_scatter_gather() {
        let s = Segment::new(256);
        let data: Vec<u8> = (0..32).collect();
        s.write_strided(0, 16, 8, &data).unwrap(); // 4 blocks of 8 at stride 16
        assert_eq!(s.read(0, 8).unwrap(), (0..8).collect::<Vec<u8>>());
        assert_eq!(s.read(16, 8).unwrap(), (8..16).collect::<Vec<u8>>());
        assert_eq!(s.read(8, 8).unwrap(), vec![0; 8]); // gaps untouched
        let back = s.read_strided(0, 16, 8, 4).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn strided_rejects_bad_payload() {
        let s = Segment::new(256);
        assert!(s.write_strided(0, 16, 8, &[0; 12]).is_err()); // not multiple
        assert!(s.write_strided(0, 16, 0, &[]).is_err());
        assert!(s.write_strided(240, 16, 8, &[0; 16]).is_err()); // out of bounds
    }

    #[test]
    fn vectored_scatter_gather() {
        let s = Segment::new(128);
        let entries = [(0u64, 4u32), (100, 8), (50, 4)];
        let data: Vec<u8> = (1..=16).collect();
        s.write_vectored(&entries, &data).unwrap();
        assert_eq!(s.read(0, 4).unwrap(), vec![1, 2, 3, 4]);
        assert_eq!(s.read(100, 8).unwrap(), (5..=12).collect::<Vec<u8>>());
        assert_eq!(s.read(50, 4).unwrap(), vec![13, 14, 15, 16]);
        assert_eq!(s.read_vectored(&entries).unwrap(), data);
    }

    #[test]
    fn vectored_rejects_mismatch() {
        let s = Segment::new(128);
        assert!(s.write_vectored(&[(0, 4)], &[0; 5]).is_err());
        assert!(s.write_vectored(&[(126, 4)], &[0; 4]).is_err());
    }

    #[test]
    fn allocator_basics() {
        let s = Segment::new(1024);
        let a = s.alloc(100).unwrap();
        let b = s.alloc(100).unwrap();
        assert_ne!(a, b);
        assert_eq!(s.allocated_bytes(), 104 + 104); // 8-byte aligned
        s.free(a).unwrap();
        let c = s.alloc(50).unwrap();
        assert_eq!(c, a); // first fit reuses the hole
        assert!(s.free(999).is_err());
    }

    #[test]
    fn allocator_exhaustion_and_coalesce() {
        let s = Segment::new(256);
        let a = s.alloc(128).unwrap();
        let b = s.alloc(128).unwrap();
        assert!(s.alloc(8).is_err());
        s.free(a).unwrap();
        s.free(b).unwrap();
        // Full coalesce: a 256-byte allocation must fit again.
        let c = s.alloc(256).unwrap();
        assert_eq!(c, 0);
    }

    #[test]
    fn allocations_never_overlap() {
        let s = Segment::new(4096);
        let mut live: Vec<(u64, usize)> = Vec::new();
        for i in 0..32 {
            let len = 32 + (i % 7) * 24;
            let off = s.alloc(len).unwrap();
            for &(o, l) in &live {
                let sep = off + len as u64 <= o || o + l as u64 <= off;
                assert!(sep, "overlap: ({off},{len}) vs ({o},{l})");
            }
            live.push((off, len));
        }
    }

    #[test]
    fn atomic_rmw_scalar_family() {
        let s = Segment::new(256);
        s.write(8, &5u64.to_le_bytes()).unwrap();
        assert_eq!(s.atomic_rmw(8, AtomicOp::FaaAdd, 3, 0).unwrap(), 5);
        assert_eq!(s.atomic_rmw(8, AtomicOp::FaaMax, 100, 0).unwrap(), 8);
        assert_eq!(s.atomic_rmw(8, AtomicOp::FaaMin, 7, 0).unwrap(), 100);
        assert_eq!(s.atomic_rmw(8, AtomicOp::FaaAnd, 0b110, 0).unwrap(), 7);
        assert_eq!(s.atomic_rmw(8, AtomicOp::FaaOr, 0b1000, 0).unwrap(), 6);
        assert_eq!(s.atomic_rmw(8, AtomicOp::FaaXor, 0b1111, 0).unwrap(), 14);
        // 14 ^ 15 = 1.
        assert_eq!(s.atomic_rmw(8, AtomicOp::Swap, 42, 0).unwrap(), 1);
        // CAS success and failure both return the old value.
        assert_eq!(s.atomic_rmw(8, AtomicOp::Cas, 42, 50).unwrap(), 42);
        assert_eq!(s.atomic_rmw(8, AtomicOp::Cas, 42, 99).unwrap(), 50);
        assert_eq!(s.read(8, 8).unwrap(), 50u64.to_le_bytes());
        // Accumulate ops are rejected on the scalar path; bounds checked.
        assert!(s.atomic_rmw(8, AtomicOp::AccSum, 1, 0).is_err());
        assert!(s.atomic_rmw(250, AtomicOp::FaaAdd, 1, 0).is_err());
    }

    #[test]
    fn atomic_rmw_handles_misaligned_offsets() {
        let s = Segment::new(64);
        // An odd offset exercises the locked fallback on any 8-aligned heap
        // base; whichever path runs, the semantics must be identical.
        s.write(3, &9u64.to_le_bytes()).unwrap();
        assert_eq!(s.atomic_rmw(3, AtomicOp::FaaAdd, 1, 0).unwrap(), 9);
        assert_eq!(s.read(3, 8).unwrap(), 10u64.to_le_bytes());
    }

    #[test]
    fn concurrent_faa_sums_exactly() {
        let s = Segment::new(64);
        let threads = 8;
        let per = 1000u64;
        let mut hs = Vec::new();
        for _ in 0..threads {
            let s2 = s.clone();
            hs.push(std::thread::spawn(move || {
                for _ in 0..per {
                    s2.atomic_rmw(0, AtomicOp::FaaAdd, 1, 0).unwrap();
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        let v = u64::from_le_bytes(s.read(0, 8).unwrap().try_into().unwrap());
        assert_eq!(v, threads as u64 * per);
    }

    #[test]
    fn accumulate_u64_and_f64_lanes() {
        let s = Segment::new(128);
        s.write(0, &collectives::encode_u64s(&[1, 10, 100])).unwrap();
        s.accumulate(0, ReduceOp::Sum, Lane::U64, &collectives::encode_u64s(&[2, 20, 200]))
            .unwrap();
        assert_eq!(
            collectives::decode_u64s(&s.read(0, 24).unwrap()).unwrap(),
            vec![3, 30, 300]
        );
        s.accumulate(0, ReduceOp::Max, Lane::U64, &collectives::encode_u64s(&[5, 25, 250]))
            .unwrap();
        assert_eq!(
            collectives::decode_u64s(&s.read(0, 24).unwrap()).unwrap(),
            vec![5, 30, 300]
        );
        s.write(64, &collectives::encode_f64s(&[1.5, -2.0])).unwrap();
        s.accumulate(64, ReduceOp::Min, Lane::F64, &collectives::encode_f64s(&[0.5, 7.0]))
            .unwrap();
        assert_eq!(
            collectives::decode_f64s(&s.read(64, 16).unwrap()).unwrap(),
            vec![0.5, -2.0]
        );
        // Ragged or empty payloads and out-of-bounds ranges are rejected.
        assert!(s.accumulate(0, ReduceOp::Sum, Lane::U64, &[0; 12]).is_err());
        assert!(s.accumulate(0, ReduceOp::Sum, Lane::U64, &[]).is_err());
        assert!(s.accumulate(124, ReduceOp::Sum, Lane::U64, &[0; 8]).is_err());
    }

    #[test]
    fn concurrent_accumulate_sums_exactly() {
        let s = Segment::new(64);
        let contribution = collectives::encode_u64s(&[1, 2, 3, 4]);
        let threads = 8;
        let per = 500;
        let mut hs = Vec::new();
        for _ in 0..threads {
            let s2 = s.clone();
            let c = contribution.clone();
            hs.push(std::thread::spawn(move || {
                for _ in 0..per {
                    s2.accumulate(0, ReduceOp::Sum, Lane::U64, &c).unwrap();
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        let n = (threads * per) as u64;
        assert_eq!(
            collectives::decode_u64s(&s.read(0, 32).unwrap()).unwrap(),
            vec![n, 2 * n, 3 * n, 4 * n]
        );
    }

    #[test]
    fn concurrent_readers_and_writer() {
        let s = Segment::new(4096);
        let s2 = s.clone();
        let w = std::thread::spawn(move || {
            for i in 0..1000u32 {
                s2.write(0, &i.to_le_bytes()).unwrap();
            }
        });
        for _ in 0..1000 {
            let v = s.read(0, 4).unwrap();
            let x = u32::from_le_bytes(v.try_into().unwrap());
            assert!(x < 1000);
        }
        w.join().unwrap();
    }
}
