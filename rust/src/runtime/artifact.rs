//! Artifact manifest schema (`artifacts/manifest.json`).
//!
//! Written by `python/compile/aot.py`; one entry per AOT-lowered tile shape.

use std::path::Path;

use crate::error::{Error, Result};
use crate::util::json::Json;

/// One lowered executable.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    /// Graph kind (currently `jacobi_step`).
    pub kind: String,
    pub rows: usize,
    pub cols: usize,
    /// Input literal shape.
    pub input: Vec<usize>,
    /// Output literal shape.
    pub output: Vec<usize>,
    pub dtype: String,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub version: usize,
    pub artifacts: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text)?;
        let version = v
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| Error::Artifact("manifest missing 'version'".into()))?;
        if version != 1 {
            return Err(Error::Artifact(format!("unsupported manifest version {version}")));
        }
        let arts = v
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Artifact("manifest missing 'artifacts'".into()))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for (i, a) in arts.iter().enumerate() {
            let field = |k: &str| -> Result<&Json> {
                a.get(k)
                    .ok_or_else(|| Error::Artifact(format!("artifact {i} missing '{k}'")))
            };
            let s = |k: &str| -> Result<String> {
                field(k)?
                    .as_str()
                    .map(str::to_string)
                    .ok_or_else(|| Error::Artifact(format!("artifact {i}: '{k}' not a string")))
            };
            let n = |k: &str| -> Result<usize> {
                field(k)?
                    .as_usize()
                    .ok_or_else(|| Error::Artifact(format!("artifact {i}: '{k}' not a number")))
            };
            let shape = |k: &str| -> Result<Vec<usize>> {
                field(k)?
                    .as_arr()
                    .map(|xs| xs.iter().filter_map(Json::as_usize).collect())
                    .ok_or_else(|| Error::Artifact(format!("artifact {i}: '{k}' not an array")))
            };
            artifacts.push(ArtifactEntry {
                name: s("name")?,
                file: s("file")?,
                kind: s("kind")?,
                rows: n("rows")?,
                cols: n("cols")?,
                input: shape("input")?,
                output: shape("output")?,
                dtype: s("dtype")?,
            });
        }
        Ok(Manifest { version, artifacts })
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Artifact(format!("cannot read {}: {e}", path.display())))?;
        Self::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "jacobi_r8_c16", "file": "jacobi_r8_c16.hlo.txt",
         "kind": "jacobi_step", "rows": 8, "cols": 16,
         "input": [10, 16], "output": [8, 16], "dtype": "f32"}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.version, 1);
        assert_eq!(m.artifacts.len(), 1);
        let a = &m.artifacts[0];
        assert_eq!(a.name, "jacobi_r8_c16");
        assert_eq!(a.input, vec![10, 16]);
        assert_eq!(a.rows, 8);
    }

    #[test]
    fn rejects_bad_version() {
        assert!(Manifest::parse(r#"{"version": 9, "artifacts": []}"#).is_err());
        assert!(Manifest::parse(r#"{"artifacts": []}"#).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        let bad = r#"{"version": 1, "artifacts": [{"name": "x"}]}"#;
        assert!(Manifest::parse(bad).is_err());
    }
}
