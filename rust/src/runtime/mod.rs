//! The XLA/PJRT runtime — hardware-kernel compute.
//!
//! The paper's hardware Jacobi kernels pair HLS control logic with "an
//! optimized VHDL core" for the stencil compute. In this reproduction the
//! control logic is the rust kernel function and the compute core is an
//! AOT-compiled XLA executable: `python/compile/aot.py` lowers the
//! JAX/Pallas sweep to HLO **text** once at build time, and [`Engine`] loads
//! it through the PJRT CPU client (`xla` crate). Python never runs at
//! request time; the rust binary is self-contained once `artifacts/` exists.
//!
//! Two backends, selected at compile time:
//!
//! - **`--features xla`** — the PJRT CPU client executes the lowered HLO
//!   modules (requires the `xla` crate's vendored dependency closure).
//! - **default (native)** — a built-in interpreter executes the same graph
//!   semantics (the Jacobi 4-neighbour sweep) directly in rust. When no
//!   `artifacts/` directory exists, a built-in manifest mirroring
//!   `aot.py --shapes`' default set is used, so clusters, tests and benches
//!   run on a machine with no Python toolchain at all. The compile-once /
//!   execute-many accounting is identical across backends.
//!
//! - [`artifact`] — the `manifest.json` schema.
//! - [`Engine`]   — compile-once / execute-many wrapper with typed helpers.

pub mod artifact;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use artifact::{ArtifactEntry, Manifest};

/// Tile shapes lowered by `aot.py` when no explicit `--shapes` is given;
/// the native backend's built-in manifest mirrors this set so the two
/// backends agree on which shapes exist (see python/compile/aot.py).
const DEFAULT_SHAPES: [(usize, usize); 9] = [
    (16, 34),
    (32, 66),
    (16, 66),
    (64, 130),
    (64, 258),
    (128, 258),
    (256, 1026),
    (256, 4098),
    (512, 4098),
];

/// Execution statistics for the perf harness.
#[derive(Debug, Default)]
pub struct EngineStats {
    pub executions: AtomicU64,
    pub exec_ns: AtomicU64,
    pub compiles: AtomicU64,
}

/// One prepared executable. On the PJRT backend this wraps the loaded
/// module; on the native backend preparation just pins the tile shape.
struct Compiled {
    #[cfg(feature = "xla")]
    exe: xla::PjRtLoadedExecutable,
    rows: usize,
    cols: usize,
}

/// Compile-once, execute-many engine.
///
/// Thread-safe: executables are compiled under a lock on first use and
/// shared afterwards. One `Engine` per process is the intended pattern
/// (hardware kernels clone the `Arc<Engine>`).
pub struct Engine {
    #[cfg(feature = "xla")]
    client: xla::PjRtClient,
    #[cfg(feature = "xla")]
    dir: PathBuf,
    manifest: Manifest,
    executables: Mutex<HashMap<String, Arc<Compiled>>>,
    stats: EngineStats,
}

// SAFETY: with the PJRT backend, the CPU client and loaded executables are
// internally synchronized; the xla crate just doesn't mark them. All
// mutation on our side is behind the Mutex above. The native backend is
// trivially Send + Sync, but the impls must cover both cfgs.
unsafe impl Send for Engine {}
// SAFETY: as for `Send` — shared access goes through the internally
// synchronized PJRT client or the Mutex-guarded executable cache.
unsafe impl Sync for Engine {}

impl Engine {
    /// Load the artifact manifest from `dir` and prepare the backend.
    pub fn load(dir: impl AsRef<Path>) -> Result<Arc<Engine>> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        Self::from_manifest(dir, manifest)
    }

    fn from_manifest(dir: PathBuf, manifest: Manifest) -> Result<Arc<Engine>> {
        #[cfg(not(feature = "xla"))]
        let _ = dir;
        Ok(Arc::new(Engine {
            #[cfg(feature = "xla")]
            client: xla::PjRtClient::cpu()?,
            #[cfg(feature = "xla")]
            dir,
            manifest,
            executables: Mutex::new(HashMap::new()),
            stats: EngineStats::default(),
        }))
    }

    /// The manifest the native backend synthesizes when `artifacts/` is
    /// absent: one `jacobi_step` entry per default AOT shape.
    fn builtin_manifest() -> Manifest {
        let artifacts = DEFAULT_SHAPES
            .iter()
            .map(|&(rows, cols)| ArtifactEntry {
                name: format!("jacobi_r{rows}_c{cols}"),
                file: format!("jacobi_r{rows}_c{cols}.hlo.txt"),
                kind: "jacobi_step".to_string(),
                rows,
                cols,
                input: vec![rows + 2, cols],
                output: vec![rows, cols],
                dtype: "f32".to_string(),
            })
            .collect();
        Manifest { version: 1, artifacts }
    }

    /// Locate the repository's `artifacts/` directory (walks up from CWD),
    /// honouring `SHOAL_ARTIFACTS` when set.
    pub fn default_dir() -> Result<PathBuf> {
        if let Ok(d) = std::env::var("SHOAL_ARTIFACTS") {
            return Ok(PathBuf::from(d));
        }
        let mut cur = std::env::current_dir()?;
        loop {
            let cand = cur.join("artifacts");
            if cand.join("manifest.json").exists() {
                return Ok(cand);
            }
            if !cur.pop() {
                return Err(Error::Artifact(
                    "artifacts/manifest.json not found; run `make artifacts`".into(),
                ));
            }
        }
    }

    /// Engine over the default artifact directory. On the native backend a
    /// missing `artifacts/` directory falls back to the built-in manifest
    /// (the default `aot.py` shape set); the PJRT backend needs real HLO
    /// files and keeps the hard error.
    pub fn load_default() -> Result<Arc<Engine>> {
        match Self::default_dir() {
            Ok(dir) => Self::load(dir),
            #[cfg(not(feature = "xla"))]
            Err(_) => Self::from_manifest(PathBuf::from("artifacts"), Self::builtin_manifest()),
            #[cfg(feature = "xla")]
            Err(e) => Err(e),
        }
    }

    /// Process-wide shared engine over the default artifact directory.
    ///
    /// Compiled executables are expensive (PJRT client + XLA compile); every
    /// cluster/epoch sharing one engine keeps the request path warm (§Perf:
    /// the heat-diffusion example recompiled per epoch before this — 130 ms
    /// of the 150 ms epoch wall time was XLA setup).
    pub fn shared() -> Result<Arc<Engine>> {
        // A mutex rather than OnceLock: initialization is fallible and
        // expensive (PJRT client + compiles on the xla backend), so
        // concurrent first callers must block on one load, not each run
        // their own and discard the losers.
        static SHARED: Mutex<Option<Arc<Engine>>> = Mutex::new(None);
        let mut guard = SHARED.lock().unwrap();
        if let Some(e) = guard.as_ref() {
            return Ok(Arc::clone(e));
        }
        let engine = Self::load_default()?;
        *guard = Some(Arc::clone(&engine));
        Ok(engine)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    /// The jacobi-step artifact for a `rows × cols` tile, if lowered.
    pub fn find_jacobi(&self, rows: usize, cols: usize) -> Option<&ArtifactEntry> {
        self.manifest
            .artifacts
            .iter()
            .find(|a| a.kind == "jacobi_step" && a.rows == rows && a.cols == cols)
    }

    /// Tile shapes available for Jacobi compute.
    pub fn jacobi_shapes(&self) -> Vec<(usize, usize)> {
        self.manifest
            .artifacts
            .iter()
            .filter(|a| a.kind == "jacobi_step")
            .map(|a| (a.rows, a.cols))
            .collect()
    }

    fn executable(&self, name: &str) -> Result<Arc<Compiled>> {
        if let Some(e) = self.executables.lock().unwrap().get(name) {
            return Ok(Arc::clone(e));
        }
        let entry = self
            .manifest
            .artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| Error::Artifact(format!("no artifact named '{name}'")))?;
        let compiled = Arc::new(self.compile_entry(entry)?);
        let mut guard = self.executables.lock().unwrap();
        // Racing compile of the same name: keep the first, count once.
        if let Some(e) = guard.get(name) {
            return Ok(Arc::clone(e));
        }
        self.stats.compiles.fetch_add(1, Ordering::Relaxed);
        guard.insert(name.to_string(), Arc::clone(&compiled));
        Ok(compiled)
    }

    #[cfg(feature = "xla")]
    fn compile_entry(&self, entry: &ArtifactEntry) -> Result<Compiled> {
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Artifact("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(Compiled { exe: self.client.compile(&comp)?, rows: entry.rows, cols: entry.cols })
    }

    #[cfg(not(feature = "xla"))]
    fn compile_entry(&self, entry: &ArtifactEntry) -> Result<Compiled> {
        Ok(Compiled { rows: entry.rows, cols: entry.cols })
    }

    /// Pre-compile an artifact (cold-start control for benchmarks).
    pub fn warm(&self, name: &str) -> Result<()> {
        self.executable(name).map(|_| ())
    }

    /// Execute one Jacobi sweep on a padded tile.
    ///
    /// `padded` is `(rows + 2) * cols` f32 values (tile + halo rows); the
    /// result is the updated `rows * cols` tile.
    pub fn jacobi_step(&self, rows: usize, cols: usize, padded: &[f32]) -> Result<Vec<f32>> {
        if padded.len() != (rows + 2) * cols {
            return Err(Error::Artifact(format!(
                "jacobi_step input length {} ≠ ({rows}+2)×{cols}",
                padded.len()
            )));
        }
        let entry = self
            .find_jacobi(rows, cols)
            .ok_or_else(|| {
                Error::Artifact(format!(
                    "no jacobi artifact for {rows}×{cols}; add it to aot.py --shapes"
                ))
            })?
            .name
            .clone();
        let exe = self.executable(&entry)?;

        let t0 = std::time::Instant::now();
        let out = Self::execute_jacobi(&exe, padded)?;
        self.stats.executions.fetch_add(1, Ordering::Relaxed);
        self.stats
            .exec_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        if out.len() != rows * cols {
            return Err(Error::Artifact(format!(
                "jacobi_step output length {} ≠ {rows}×{cols}",
                out.len()
            )));
        }
        Ok(out)
    }

    #[cfg(feature = "xla")]
    fn execute_jacobi(exe: &Compiled, padded: &[f32]) -> Result<Vec<f32>> {
        let (rows, cols) = (exe.rows, exe.cols);
        let input = xla::Literal::vec1(padded).reshape(&[(rows + 2) as i64, cols as i64])?;
        let result = exe.exe.execute::<xla::Literal>(&[input])?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple1()?;
        Ok(tuple.to_vec::<f32>()?)
    }

    /// Native interpreter for the lowered graph: the interior 4-neighbour
    /// average with boundary columns copied through — exactly the semantics
    /// `aot.py` lowers (and `ref.py` / `RustSweep` assert against).
    #[cfg(not(feature = "xla"))]
    fn execute_jacobi(exe: &Compiled, padded: &[f32]) -> Result<Vec<f32>> {
        let (rows, cols) = (exe.rows, exe.cols);
        let mut out = vec![0f32; rows * cols];
        for r in 0..rows {
            let up = &padded[r * cols..(r + 1) * cols];
            let mid = &padded[(r + 1) * cols..(r + 2) * cols];
            let down = &padded[(r + 2) * cols..(r + 3) * cols];
            let dst = &mut out[r * cols..(r + 1) * cols];
            dst[0] = mid[0];
            dst[cols - 1] = mid[cols - 1];
            for c in 1..cols - 1 {
                dst[c] = 0.25 * (up[c] + down[c] + mid[c - 1] + mid[c + 1]);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The serial oracle shared with the python ref (ref.py
    /// jacobi_step_ref): interior 4-neighbour average, boundary columns
    /// copied through.
    pub fn jacobi_step_oracle(rows: usize, cols: usize, padded: &[f32]) -> Vec<f32> {
        let at = |r: usize, c: usize| padded[r * cols + c];
        let mut out = vec![0f32; rows * cols];
        for r in 0..rows {
            let pr = r + 1;
            out[r * cols] = at(pr, 0);
            out[r * cols + cols - 1] = at(pr, cols - 1);
            for c in 1..cols - 1 {
                out[r * cols + c] =
                    0.25 * (at(pr - 1, c) + at(pr + 1, c) + at(pr, c - 1) + at(pr, c + 1));
            }
        }
        out
    }

    fn engine() -> Arc<Engine> {
        Engine::load_default().expect("run `make artifacts` first")
    }

    #[test]
    fn manifest_has_jacobi_shapes() {
        let e = engine();
        assert!(e.find_jacobi(16, 34).is_some());
        assert!(e.find_jacobi(32, 66).is_some());
        assert!(e.find_jacobi(7, 13).is_none());
    }

    #[test]
    fn jacobi_step_matches_oracle() {
        let e = engine();
        let (rows, cols) = (16, 34);
        let padded: Vec<f32> = (0..(rows + 2) * cols).map(|i| (i % 97) as f32 * 0.5).collect();
        let got = e.jacobi_step(rows, cols, &padded).unwrap();
        let want = jacobi_step_oracle(rows, cols, &padded);
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-4, "idx {i}: {g} vs {w}");
        }
    }

    #[test]
    fn executables_are_cached() {
        let e = engine();
        let padded = vec![1.0f32; 18 * 34];
        e.jacobi_step(16, 34, &padded).unwrap();
        e.jacobi_step(16, 34, &padded).unwrap();
        assert_eq!(e.stats().compiles.load(Ordering::Relaxed), 1);
        assert_eq!(e.stats().executions.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn input_validation() {
        let e = engine();
        assert!(e.jacobi_step(16, 34, &[0.0; 10]).is_err());
        assert!(e.jacobi_step(7, 13, &vec![0.0; 9 * 13]).is_err());
    }

    #[test]
    fn concurrent_executions() {
        let e = engine();
        e.warm("jacobi_r16_c34").unwrap();
        let mut threads = Vec::new();
        for t in 0..4 {
            let e2 = Arc::clone(&e);
            threads.push(std::thread::spawn(move || {
                let padded: Vec<f32> =
                    (0..18 * 34).map(|i| ((i + t) % 31) as f32).collect();
                let got = e2.jacobi_step(16, 34, &padded).unwrap();
                let want = jacobi_step_oracle(16, 34, &padded);
                for (g, w) in got.iter().zip(&want) {
                    assert!((g - w).abs() < 1e-4);
                }
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
    }
}
