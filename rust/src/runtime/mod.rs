//! The XLA/PJRT runtime — hardware-kernel compute.
//!
//! The paper's hardware Jacobi kernels pair HLS control logic with "an
//! optimized VHDL core" for the stencil compute. In this reproduction the
//! control logic is the rust kernel function and the compute core is an
//! AOT-compiled XLA executable: `python/compile/aot.py` lowers the
//! JAX/Pallas sweep to HLO **text** once at build time, and [`Engine`] loads
//! it through the PJRT CPU client (`xla` crate). Python never runs at
//! request time; the rust binary is self-contained once `artifacts/` exists.
//!
//! - [`artifact`] — the `manifest.json` schema.
//! - [`Engine`]   — compile-once / execute-many wrapper with typed helpers.

pub mod artifact;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use artifact::{ArtifactEntry, Manifest};

/// Execution statistics for the perf harness.
#[derive(Debug, Default)]
pub struct EngineStats {
    pub executions: AtomicU64,
    pub exec_ns: AtomicU64,
    pub compiles: AtomicU64,
}

/// Compile-once, execute-many PJRT engine.
///
/// Thread-safe: executables are compiled under a lock on first use and
/// shared afterwards. One `Engine` per process is the intended pattern
/// (hardware kernels clone the `Arc<Engine>`).
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    executables: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    stats: EngineStats,
}

// The PJRT CPU client and loaded executables are internally synchronized;
// the xla crate just doesn't mark them. All mutation on our side is behind
// the Mutex above.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Load the artifact manifest from `dir` and create the PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Arc<Engine>> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Arc::new(Engine {
            client,
            dir,
            manifest,
            executables: Mutex::new(HashMap::new()),
            stats: EngineStats::default(),
        }))
    }

    /// Locate the repository's `artifacts/` directory (walks up from CWD),
    /// honouring `SHOAL_ARTIFACTS` when set.
    pub fn default_dir() -> Result<PathBuf> {
        if let Ok(d) = std::env::var("SHOAL_ARTIFACTS") {
            return Ok(PathBuf::from(d));
        }
        let mut cur = std::env::current_dir()?;
        loop {
            let cand = cur.join("artifacts");
            if cand.join("manifest.json").exists() {
                return Ok(cand);
            }
            if !cur.pop() {
                return Err(Error::Artifact(
                    "artifacts/manifest.json not found; run `make artifacts`".into(),
                ));
            }
        }
    }

    /// Engine over the default artifact directory.
    pub fn load_default() -> Result<Arc<Engine>> {
        Self::load(Self::default_dir()?)
    }

    /// Process-wide shared engine over the default artifact directory.
    ///
    /// Compiled executables are expensive (PJRT client + XLA compile); every
    /// cluster/epoch sharing one engine keeps the request path warm (§Perf:
    /// the heat-diffusion example recompiled per epoch before this — 130 ms
    /// of the 150 ms epoch wall time was XLA setup).
    pub fn shared() -> Result<Arc<Engine>> {
        static SHARED: once_cell::sync::OnceCell<Arc<Engine>> = once_cell::sync::OnceCell::new();
        SHARED.get_or_try_init(Self::load_default).map(Arc::clone)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    /// The jacobi-step artifact for a `rows × cols` tile, if lowered.
    pub fn find_jacobi(&self, rows: usize, cols: usize) -> Option<&ArtifactEntry> {
        self.manifest
            .artifacts
            .iter()
            .find(|a| a.kind == "jacobi_step" && a.rows == rows && a.cols == cols)
    }

    /// Tile shapes available for Jacobi compute.
    pub fn jacobi_shapes(&self) -> Vec<(usize, usize)> {
        self.manifest
            .artifacts
            .iter()
            .filter(|a| a.kind == "jacobi_step")
            .map(|a| (a.rows, a.cols))
            .collect()
    }

    fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.executables.lock().unwrap().get(name) {
            return Ok(Arc::clone(e));
        }
        let entry = self
            .manifest
            .artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| Error::Artifact(format!("no artifact named '{name}'")))?;
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Artifact("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp)?);
        self.stats.compiles.fetch_add(1, Ordering::Relaxed);
        let mut guard = self.executables.lock().unwrap();
        let e = guard.entry(name.to_string()).or_insert(exe);
        Ok(Arc::clone(e))
    }

    /// Pre-compile an artifact (cold-start control for benchmarks).
    pub fn warm(&self, name: &str) -> Result<()> {
        self.executable(name).map(|_| ())
    }

    /// Execute one Jacobi sweep on a padded tile.
    ///
    /// `padded` is `(rows + 2) * cols` f32 values (tile + halo rows); the
    /// result is the updated `rows * cols` tile.
    pub fn jacobi_step(&self, rows: usize, cols: usize, padded: &[f32]) -> Result<Vec<f32>> {
        if padded.len() != (rows + 2) * cols {
            return Err(Error::Artifact(format!(
                "jacobi_step input length {} ≠ ({rows}+2)×{cols}",
                padded.len()
            )));
        }
        let entry = self
            .find_jacobi(rows, cols)
            .ok_or_else(|| {
                Error::Artifact(format!(
                    "no jacobi artifact for {rows}×{cols}; add it to aot.py --shapes"
                ))
            })?
            .name
            .clone();
        let exe = self.executable(&entry)?;

        let t0 = std::time::Instant::now();
        let input = xla::Literal::vec1(padded).reshape(&[(rows + 2) as i64, cols as i64])?;
        let result = exe.execute::<xla::Literal>(&[input])?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple1()?;
        let out = tuple.to_vec::<f32>()?;
        self.stats.executions.fetch_add(1, Ordering::Relaxed);
        self.stats
            .exec_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        if out.len() != rows * cols {
            return Err(Error::Artifact(format!(
                "jacobi_step output length {} ≠ {rows}×{cols}",
                out.len()
            )));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The serial oracle shared with the python ref (ref.py
    /// jacobi_step_ref): interior 4-neighbour average, boundary columns
    /// copied through.
    pub fn jacobi_step_oracle(rows: usize, cols: usize, padded: &[f32]) -> Vec<f32> {
        let at = |r: usize, c: usize| padded[r * cols + c];
        let mut out = vec![0f32; rows * cols];
        for r in 0..rows {
            let pr = r + 1;
            out[r * cols] = at(pr, 0);
            out[r * cols + cols - 1] = at(pr, cols - 1);
            for c in 1..cols - 1 {
                out[r * cols + c] =
                    0.25 * (at(pr - 1, c) + at(pr + 1, c) + at(pr, c - 1) + at(pr, c + 1));
            }
        }
        out
    }

    fn engine() -> Arc<Engine> {
        Engine::load_default().expect("run `make artifacts` first")
    }

    #[test]
    fn manifest_has_jacobi_shapes() {
        let e = engine();
        assert!(e.find_jacobi(16, 34).is_some());
        assert!(e.find_jacobi(32, 66).is_some());
        assert!(e.find_jacobi(7, 13).is_none());
    }

    #[test]
    fn jacobi_step_matches_oracle() {
        let e = engine();
        let (rows, cols) = (16, 34);
        let padded: Vec<f32> = (0..(rows + 2) * cols).map(|i| (i % 97) as f32 * 0.5).collect();
        let got = e.jacobi_step(rows, cols, &padded).unwrap();
        let want = jacobi_step_oracle(rows, cols, &padded);
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-4, "idx {i}: {g} vs {w}");
        }
    }

    #[test]
    fn executables_are_cached() {
        let e = engine();
        let padded = vec![1.0f32; 18 * 34];
        e.jacobi_step(16, 34, &padded).unwrap();
        e.jacobi_step(16, 34, &padded).unwrap();
        assert_eq!(e.stats().compiles.load(Ordering::Relaxed), 1);
        assert_eq!(e.stats().executions.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn input_validation() {
        let e = engine();
        assert!(e.jacobi_step(16, 34, &[0.0; 10]).is_err());
        assert!(e.jacobi_step(7, 13, &vec![0.0; 9 * 13]).is_err());
    }

    #[test]
    fn concurrent_executions() {
        let e = engine();
        e.warm("jacobi_r16_c34").unwrap();
        let mut threads = Vec::new();
        for t in 0..4 {
            let e2 = Arc::clone(&e);
            threads.push(std::thread::spawn(move || {
                let padded: Vec<f32> =
                    (0..18 * 34).map(|i| ((i + t) % 31) as f32).collect();
                let got = e2.jacobi_step(16, 34, &padded).unwrap();
                let want = jacobi_step_oracle(16, 34, &padded);
                for (g, w) in got.iter().zip(&want) {
                    assert!((g - w).abs() < 1e-4);
                }
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
    }
}
