//! `ShoalKernel` — the heterogeneous communication API (paper §III-A).
//!
//! The same function prototypes serve software and hardware kernels; only
//! the runtime behind them differs (handler thread vs. GAScore). Message
//! classes:
//!
//! | call                  | class        | payload source | destination    |
//! |-----------------------|--------------|----------------|----------------|
//! | `am_short`            | Short        | —              | handler only   |
//! | `am_medium`           | Medium FIFO  | kernel         | kernel stream  |
//! | `am_medium_from_mem`  | Medium       | shared memory  | kernel stream  |
//! | `am_long`             | Long FIFO    | kernel         | shared memory  |
//! | `am_long_from_mem`    | Long         | shared memory  | shared memory  |
//! | `am_long_strided`     | Long Strided | kernel         | strided scatter|
//! | `am_long_vectored`    | Long Vectored| kernel         | extent scatter |
//! | `am_medium_get`       | Medium get   | remote memory  | kernel stream  |
//! | `am_long_get`         | Long get     | remote memory  | local memory   |
//!
//! Completion is per operation: every `am_*` send returns an [`AmHandle`]
//! registered in the kernel's completion table (a multi-chunk send returns
//! one handle covering all its chunks). [`wait`](ShoalKernel::wait),
//! [`test`](ShoalKernel::test), [`wait_all`](ShoalKernel::wait_all) and
//! [`wait_any`](ShoalKernel::wait_any) consume handles, which is what lets a
//! kernel overlap independent transfers with compute and attribute failures
//! to the exact operation. The paper's collective counter model ("send
//! several messages and then collectively wait for the same number of
//! replies") survives as the [`wait_replies`](ShoalKernel::wait_replies)
//! shim over the same table — each operation's completion must be consumed
//! exactly once, by a handle wait *or* by `wait_replies`, never both.

use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Duration;

use crate::am::completion::{AmHandle, CompletionTable};
use crate::am::engine::{barrier_op, BarrierState, ReceivedMedium};
use crate::am::handlers::HandlerTable;
use crate::am::header::{AmMessage, Descriptor};
use crate::am::types::{handler_ids, AmFlags, AmType};
use crate::config::{ApiProfile, ChunkPolicy, ClusterSpec};
use crate::error::{Error, Result};
use crate::galapagos::packet::Packet;
use crate::galapagos::router::RouterMsg;
use crate::memory::Segment;

pub use crate::am::engine::ReceivedMedium as Medium;

/// Default timeout for blocking waits. Generous: the Jacobi benchmarks keep
/// thousands of AMs in flight over loopback TCP.
pub const DEFAULT_TIMEOUT: Duration = Duration::from_secs(120);

/// The per-kernel API handle. Obtained from
/// [`ShoalCluster`](crate::shoal_node::cluster::ShoalCluster); moved into the
/// kernel function's thread.
pub struct ShoalKernel {
    pub(crate) id: u16,
    pub(crate) spec: Arc<ClusterSpec>,
    pub(crate) router_tx: std::sync::mpsc::Sender<RouterMsg>,
    pub(crate) segment: Segment,
    pub(crate) completion: Arc<CompletionTable>,
    pub(crate) barrier_state: Arc<BarrierState>,
    pub(crate) handlers: Arc<HandlerTable>,
    pub(crate) medium_rx: Receiver<ReceivedMedium>,
    /// Replies consumed by previous waits (`wait_replies` shim bookkeeping).
    consumed: u64,
    /// Barrier epoch counter (local).
    epoch: u64,
    pub timeout: Duration,
}

impl ShoalKernel {
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        id: u16,
        spec: Arc<ClusterSpec>,
        router_tx: std::sync::mpsc::Sender<RouterMsg>,
        segment: Segment,
        completion: Arc<CompletionTable>,
        barrier_state: Arc<BarrierState>,
        handlers: Arc<HandlerTable>,
        medium_rx: Receiver<ReceivedMedium>,
    ) -> ShoalKernel {
        ShoalKernel {
            id,
            spec,
            router_tx,
            segment,
            completion,
            barrier_state,
            handlers,
            medium_rx,
            consumed: 0,
            epoch: 0,
            timeout: DEFAULT_TIMEOUT,
        }
    }

    /// This kernel's globally unique id.
    pub fn id(&self) -> u16 {
        self.id
    }

    /// Number of kernels in the cluster.
    pub fn kernel_count(&self) -> usize {
        self.spec.kernel_count()
    }

    /// This kernel's partition of the global address space (local access —
    /// the cheap side of the PGAS local/remote distinction).
    pub fn mem(&self) -> &Segment {
        &self.segment
    }

    /// The cluster description.
    pub fn cluster(&self) -> &ClusterSpec {
        &self.spec
    }

    fn profile(&self) -> &ApiProfile {
        &self.spec.profile
    }

    fn send_msg(&self, msg: &AmMessage) -> Result<()> {
        let bytes = msg.encode()?;
        let pkt = Packet::new(msg.dst, msg.src, bytes)?;
        self.router_tx
            .send(RouterMsg::FromKernel(pkt))
            .map_err(|_| Error::Disconnected("router"))
    }

    /// Stamp one chunk's token + HANDLE flag onto `msg` and send it. A send
    /// failure propagates *into the handle*: the operation transitions to
    /// failed (the reason surfaces as [`Error::OperationFailed`] at
    /// `wait`/`test`) and `false` tells chunk loops to stop early — the
    /// `am_*` call still returns the handle, so the failure is attributed to
    /// the exact operation rather than lost in a batch.
    fn send_tracked(&self, h: AmHandle, msg: &mut AmMessage) -> bool {
        msg.token = self.completion.bind_token(h);
        msg.flags = msg.flags.with(AmFlags::HANDLE);
        match self.send_msg(msg) {
            Ok(()) => true,
            Err(e) => {
                log::warn!("kernel {}: send failed; failing its handle: {e}", self.id);
                self.completion.fail(h, &format!("send failed: {e}"));
                false
            }
        }
    }

    // -- Short ---------------------------------------------------------------

    /// Send a Short AM (signaling; no payload). Returns after local emit.
    pub fn am_short(&mut self, dst: u16, handler: u8, args: &[u64]) -> Result<AmHandle> {
        self.am_short_flags(dst, handler, args, AmFlags::new())
    }

    /// Asynchronous Short AM — no reply will be generated; the returned
    /// handle is already complete.
    pub fn am_short_async(&mut self, dst: u16, handler: u8, args: &[u64]) -> Result<AmHandle> {
        self.am_short_flags(dst, handler, args, AmFlags::new().with(AmFlags::ASYNC))
    }

    fn am_short_flags(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        flags: AmFlags,
    ) -> Result<AmHandle> {
        if !self.profile().short {
            return Err(Error::ProfileViolation("short"));
        }
        self.spec.kernel(dst)?;
        let mut msg = AmMessage {
            am_type: AmType::Short,
            flags,
            src: self.id,
            dst,
            handler,
            token: 0,
            args: args.to_vec(),
            desc: Descriptor::None,
            payload: vec![],
        };
        if flags.is_async() {
            self.send_msg(&msg)?;
            return Ok(AmHandle::completed());
        }
        let h = self.completion.create(1);
        self.send_tracked(h, &mut msg);
        Ok(h)
    }

    // -- Medium ---------------------------------------------------------------

    /// Medium FIFO put: payload from this kernel to the destination kernel's
    /// stream ("point-to-point communication for one kernel to send data
    /// directly to another kernel").
    pub fn am_medium(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
    ) -> Result<AmHandle> {
        self.medium_impl(dst, handler, args, payload.to_vec(), AmFlags::new().with(AmFlags::FIFO))
    }

    /// Asynchronous Medium FIFO put.
    pub fn am_medium_async(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
    ) -> Result<AmHandle> {
        self.medium_impl(
            dst,
            handler,
            args,
            payload.to_vec(),
            AmFlags::new().with(AmFlags::FIFO).with(AmFlags::ASYNC),
        )
    }

    /// Medium put whose payload the runtime reads from this kernel's memory
    /// partition (`src_offset`, `len`) — the non-FIFO variant of §III-A.
    pub fn am_medium_from_mem(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        src_offset: u64,
        len: usize,
    ) -> Result<AmHandle> {
        let payload = self.segment.read(src_offset, len)?;
        self.medium_impl(dst, handler, args, payload, AmFlags::new())
    }

    fn medium_impl(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: Vec<u8>,
        flags: AmFlags,
    ) -> Result<AmHandle> {
        if !self.profile().medium {
            return Err(Error::ProfileViolation("medium"));
        }
        self.spec.kernel(dst)?;
        let mut msg = AmMessage {
            am_type: AmType::Medium,
            flags,
            src: self.id,
            dst,
            handler,
            token: 0,
            args: args.to_vec(),
            desc: Descriptor::None,
            payload,
        };
        if msg.payload.len() > msg.max_payload_for() {
            // Medium payloads are a kernel-stream datum; chunking would change
            // message boundaries, so it is always an error (the Jacobi halo
            // exchange failure mode of §IV-C1).
            return Err(Error::AmTooLarge {
                payload: msg.payload.len(),
                limit: msg.max_payload_for(),
            });
        }
        if flags.is_async() {
            self.send_msg(&msg)?;
            return Ok(AmHandle::completed());
        }
        let h = self.completion.create(1);
        self.send_tracked(h, &mut msg);
        Ok(h)
    }

    /// Medium get: bring `len` bytes at `src_addr` in the destination
    /// kernel's partition back to this kernel's stream. The data arrives as
    /// a [`ReceivedMedium`] per emitted chunk; the handle completes when
    /// every chunk's data reply has arrived.
    pub fn am_medium_get(
        &mut self,
        dst: u16,
        handler: u8,
        src_addr: u64,
        len: usize,
    ) -> Result<AmHandle> {
        if !self.profile().medium || !self.profile().gets {
            return Err(Error::ProfileViolation("medium get"));
        }
        self.spec.kernel(dst)?;
        let probe = AmMessage {
            am_type: AmType::Medium,
            flags: AmFlags::new().with(AmFlags::GET),
            src: self.id,
            dst,
            handler,
            token: 0,
            args: vec![0],
            desc: Descriptor::MediumGet { src_addr, len: 0 },
            payload: vec![],
        };
        let max = probe.max_payload_for();
        let chunks = self.chunk_ranges(len, max)?;
        // Validate every chunk's address arithmetic *before* registering the
        // operation, so an overflow cannot abandon a half-issued handle.
        let descs = chunks
            .iter()
            .map(|&(off, clen)| {
                Ok((off, Descriptor::MediumGet {
                    src_addr: checked_offset(src_addr, off)?,
                    len: clen as u32,
                }))
            })
            .collect::<Result<Vec<_>>>()?;
        let h = self.completion.create(descs.len() as u64);
        for (off, desc) in descs {
            let mut msg = AmMessage {
                am_type: AmType::Medium,
                flags: AmFlags::new().with(AmFlags::GET),
                src: self.id,
                dst,
                handler,
                token: 0,
                // Final arg carries the chunk's byte offset so the receiver
                // can reassemble multi-chunk gets.
                args: vec![off],
                desc,
                payload: vec![],
            };
            if !self.send_tracked(h, &mut msg) {
                break;
            }
        }
        Ok(h)
    }

    // -- Long -----------------------------------------------------------------

    /// Long FIFO put: payload from this kernel, written into the destination
    /// kernel's partition at `dst_addr`.
    pub fn am_long(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
        dst_addr: u64,
    ) -> Result<AmHandle> {
        self.long_impl(dst, handler, args, payload, dst_addr, AmFlags::new().with(AmFlags::FIFO))
    }

    /// Asynchronous Long FIFO put.
    pub fn am_long_async(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
        dst_addr: u64,
    ) -> Result<AmHandle> {
        self.long_impl(
            dst,
            handler,
            args,
            payload,
            dst_addr,
            AmFlags::new().with(AmFlags::FIFO).with(AmFlags::ASYNC),
        )
    }

    /// Long put whose payload the runtime reads from this kernel's partition.
    pub fn am_long_from_mem(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        src_offset: u64,
        len: usize,
        dst_addr: u64,
    ) -> Result<AmHandle> {
        let payload = self.segment.read(src_offset, len)?;
        self.long_impl(dst, handler, args, &payload, dst_addr, AmFlags::new())
    }

    fn long_impl(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
        dst_addr: u64,
        flags: AmFlags,
    ) -> Result<AmHandle> {
        if !self.profile().long {
            return Err(Error::ProfileViolation("long"));
        }
        self.spec.kernel(dst)?;
        let probe = AmMessage {
            am_type: AmType::Long,
            flags,
            src: self.id,
            dst,
            handler,
            token: 0,
            args: args.to_vec(),
            desc: Descriptor::Long { dst_addr },
            payload: vec![],
        };
        let max = probe.max_payload_for();
        let chunks = self.chunk_ranges(payload.len(), max)?;
        // Address arithmetic validated before the operation is registered.
        let descs = chunks
            .iter()
            .map(|&(off, clen)| {
                Ok((off, clen, Descriptor::Long { dst_addr: checked_offset(dst_addr, off)? }))
            })
            .collect::<Result<Vec<_>>>()?;
        let h = if flags.is_async() {
            AmHandle::completed()
        } else {
            self.completion.create(descs.len() as u64)
        };
        for (off, clen, desc) in descs {
            let mut msg = AmMessage {
                am_type: AmType::Long,
                flags,
                src: self.id,
                dst,
                handler,
                token: 0,
                args: args.to_vec(),
                desc,
                payload: payload[off as usize..off as usize + clen].to_vec(),
            };
            if flags.is_async() {
                self.send_msg(&msg)?;
            } else if !self.send_tracked(h, &mut msg) {
                break;
            }
        }
        Ok(h)
    }

    /// Long get: read `len` bytes at `src_addr` in the destination kernel's
    /// partition; the reply writes them at `reply_addr` in *this* kernel's
    /// partition. Completion = `wait(handle)` (or the `wait_replies` shim).
    pub fn am_long_get(
        &mut self,
        dst: u16,
        handler: u8,
        src_addr: u64,
        len: usize,
        reply_addr: u64,
    ) -> Result<AmHandle> {
        if !self.profile().long || !self.profile().gets {
            return Err(Error::ProfileViolation("long get"));
        }
        self.spec.kernel(dst)?;
        let probe = AmMessage {
            am_type: AmType::Long,
            flags: AmFlags::new().with(AmFlags::REPLY),
            src: dst,
            dst: self.id,
            handler,
            token: 0,
            args: vec![],
            desc: Descriptor::Long { dst_addr: reply_addr },
            payload: vec![],
        };
        let max = probe.max_payload_for();
        let chunks = self.chunk_ranges(len, max)?;
        // Address arithmetic validated before the operation is registered.
        let descs = chunks
            .iter()
            .map(|&(off, clen)| {
                Ok(Descriptor::LongGet {
                    src_addr: checked_offset(src_addr, off)?,
                    len: clen as u32,
                    reply_addr: checked_offset(reply_addr, off)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let h = self.completion.create(descs.len() as u64);
        for desc in descs {
            let mut msg = AmMessage {
                am_type: AmType::Long,
                flags: AmFlags::new().with(AmFlags::GET),
                src: self.id,
                dst,
                handler,
                token: 0,
                args: vec![],
                desc,
                payload: vec![],
            };
            if !self.send_tracked(h, &mut msg) {
                break;
            }
        }
        Ok(h)
    }

    /// Strided Long put: block `i` of `block_len` bytes lands at
    /// `dst_addr + i*stride` in the destination's partition.
    pub fn am_long_strided(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
        dst_addr: u64,
        stride: u32,
        block_len: u32,
    ) -> Result<AmHandle> {
        if !self.profile().strided {
            return Err(Error::ProfileViolation("strided"));
        }
        self.spec.kernel(dst)?;
        if block_len == 0 || payload.len() % block_len as usize != 0 {
            return Err(Error::BadDescriptor(format!(
                "strided payload {} not a multiple of block_len {block_len}",
                payload.len()
            )));
        }
        let nblocks = (payload.len() / block_len as usize) as u32;
        let mut msg = AmMessage {
            am_type: AmType::LongStrided,
            flags: AmFlags::new().with(AmFlags::FIFO),
            src: self.id,
            dst,
            handler,
            token: 0,
            args: args.to_vec(),
            desc: Descriptor::Strided { dst_addr, stride, block_len, nblocks },
            payload: payload.to_vec(),
        };
        if msg.payload.len() > msg.max_payload_for() {
            return Err(Error::AmTooLarge {
                payload: msg.payload.len(),
                limit: msg.max_payload_for(),
            });
        }
        let h = self.completion.create(1);
        self.send_tracked(h, &mut msg);
        Ok(h)
    }

    /// Vectored Long put: payload split over explicit (addr, len) extents.
    pub fn am_long_vectored(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
        entries: &[(u64, u32)],
    ) -> Result<AmHandle> {
        if !self.profile().vectored {
            return Err(Error::ProfileViolation("vectored"));
        }
        self.spec.kernel(dst)?;
        let mut msg = AmMessage {
            am_type: AmType::LongVectored,
            flags: AmFlags::new().with(AmFlags::FIFO),
            src: self.id,
            dst,
            handler,
            token: 0,
            args: args.to_vec(),
            desc: Descriptor::Vectored { entries: entries.to_vec() },
            payload: payload.to_vec(),
        };
        msg.validate()?;
        if msg.payload.len() > msg.max_payload_for() {
            return Err(Error::AmTooLarge {
                payload: msg.payload.len(),
                limit: msg.max_payload_for(),
            });
        }
        let h = self.completion.create(1);
        self.send_tracked(h, &mut msg);
        Ok(h)
    }

    // -- completion ------------------------------------------------------------

    /// Block until `h` completes, consuming it. A failed send surfaces its
    /// reason as [`Error::OperationFailed`]; a timeout leaves the operation
    /// outstanding. Waiting an already-consumed handle succeeds without
    /// double-crediting the `wait_replies` bookkeeping.
    pub fn wait(&mut self, h: AmHandle) -> Result<()> {
        if self.completion.wait(h, self.timeout)? {
            self.consumed += h.messages;
        }
        Ok(())
    }

    /// Nonblocking completion probe: `Ok(true)` consumes the handle,
    /// `Ok(false)` means still in flight, `Err` surfaces a failed send.
    pub fn test(&mut self, h: AmHandle) -> Result<bool> {
        match self.completion.test(h)? {
            None => Ok(false),
            Some(first) => {
                if first {
                    self.consumed += h.messages;
                }
                Ok(true)
            }
        }
    }

    /// Block until every handle in `hs` completes (consuming all of them) —
    /// the fence after a batch of overlapped transfers. Handles already
    /// consumed (e.g. by an earlier `wait_any`) are skipped harmlessly.
    pub fn wait_all(&mut self, hs: &[AmHandle]) -> Result<()> {
        let deadline = std::time::Instant::now() + self.timeout;
        for h in hs {
            let now = std::time::Instant::now();
            let left = if now >= deadline { Duration::ZERO } else { deadline - now };
            if self.completion.wait(*h, left)? {
                self.consumed += h.messages;
            }
        }
        Ok(())
    }

    /// Block until *any* handle in `hs` completes; returns the index of the
    /// completed handle (consuming only that one).
    pub fn wait_any(&mut self, hs: &[AmHandle]) -> Result<usize> {
        let (i, first) = self.completion.wait_any(hs, self.timeout)?;
        if first {
            self.consumed += hs[i].messages;
        }
        Ok(i)
    }

    /// Block until `n` more replies have arrived — the paper's collective
    /// completion model, retained as a shim over the completion table
    /// (callers sum the `AmHandle::messages` of the operations they wait
    /// on). Do not mix with handle waits *for the same operations*.
    pub fn wait_replies(&mut self, n: u64) -> Result<()> {
        let target = self.consumed + n;
        self.completion.wait_total(target, self.timeout)?;
        self.consumed = target;
        Ok(())
    }

    /// Replies received but not yet consumed by any wait. Saturates at zero:
    /// double-consuming a handle (wait/test after it already settled) can
    /// push the consumed count past the resolved count.
    pub fn pending_replies(&self) -> u64 {
        self.completion.resolved_total().saturating_sub(self.consumed)
    }

    /// Blocking receive of the next Medium payload.
    pub fn recv_medium(&self) -> Result<ReceivedMedium> {
        self.medium_rx
            .recv_timeout(self.timeout)
            .map_err(|e| match e {
                std::sync::mpsc::RecvTimeoutError::Timeout => Error::Timeout("medium receive"),
                _ => Error::Disconnected("medium stream"),
            })
    }

    /// Non-blocking receive.
    pub fn try_recv_medium(&self) -> Result<Option<ReceivedMedium>> {
        match self.medium_rx.try_recv() {
            Ok(m) => Ok(Some(m)),
            Err(std::sync::mpsc::TryRecvError::Empty) => Ok(None),
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                Err(Error::Disconnected("medium stream"))
            }
        }
    }

    /// Register a user handler (software kernels only, as in the paper).
    pub fn register_handler(
        &self,
        id: u8,
        f: impl Fn(crate::am::handlers::HandlerArgs<'_>) + Send + Sync + 'static,
    ) -> Result<()> {
        if !self.profile().user_handlers {
            return Err(Error::ProfileViolation("user handlers"));
        }
        self.handlers.register(id, Box::new(f))
    }

    // -- barrier ----------------------------------------------------------------

    /// Cluster-wide barrier over Short AMs. The lowest kernel id acts as the
    /// master: it counts ENTER messages and broadcasts RELEASE.
    pub fn barrier(&mut self) -> Result<()> {
        if !self.profile().barrier {
            return Err(Error::ProfileViolation("barrier"));
        }
        self.epoch += 1;
        let epoch = self.epoch;
        let mut ids: Vec<u16> = self.spec.kernels.iter().map(|k| k.id).collect();
        ids.sort_unstable();
        let master = ids[0];
        let n = ids.len() as u64;
        if n == 1 {
            return Ok(());
        }
        if self.id == master {
            // Seed membership so a timeout names never-arrived kernels too.
            self.barrier_state.note_members(&ids[1..]);
            self.barrier_state
                .wait_enters(epoch, n - 1, self.timeout)?;
            for &kid in ids.iter().skip(1) {
                self.am_short_async(
                    kid,
                    handler_ids::BARRIER,
                    &[barrier_op::RELEASE, epoch],
                )?;
            }
            Ok(())
        } else {
            self.am_short_async(master, handler_ids::BARRIER, &[barrier_op::ENTER, epoch])?;
            self.barrier_state.wait_release(epoch, self.timeout)
        }
    }

    // -- helpers ----------------------------------------------------------------

    /// Split `len` bytes into per-message ranges obeying the packet cap and
    /// the cluster chunk policy. Returns (offset, len) pairs.
    fn chunk_ranges(&self, len: usize, max: usize) -> Result<Vec<(u64, usize)>> {
        if len <= max {
            return Ok(vec![(0, len)]);
        }
        match self.spec.chunk_policy {
            ChunkPolicy::Reject => Err(Error::AmTooLarge { payload: len, limit: max }),
            ChunkPolicy::Chunked => {
                let mut out = Vec::with_capacity(len.div_ceil(max));
                let mut off = 0usize;
                while off < len {
                    let clen = max.min(len - off);
                    out.push((off as u64, clen));
                    off += clen;
                }
                Ok(out)
            }
        }
    }
}

/// Chunk address arithmetic with overflow detection — a silent `u64` wrap
/// here would scatter a chunk to the bottom of the destination partition.
fn checked_offset(base: u64, off: u64) -> Result<u64> {
    base.checked_add(off).ok_or_else(|| {
        Error::BadDescriptor(format!("address overflow: {base:#x} + {off:#x} exceeds u64"))
    })
}
