//! `ShoalKernel` — the heterogeneous communication API (paper §III-A).
//!
//! The same function prototypes serve software and hardware kernels; only
//! the runtime behind them differs (handler thread vs. GAScore). Message
//! classes:
//!
//! | call                  | class        | payload source | destination    |
//! |-----------------------|--------------|----------------|----------------|
//! | `am_short`            | Short        | —              | handler only   |
//! | `am_medium`           | Medium FIFO  | kernel         | kernel stream  |
//! | `am_medium_from_mem`  | Medium       | shared memory  | kernel stream  |
//! | `am_long`             | Long FIFO    | kernel         | shared memory  |
//! | `am_long_from_mem`    | Long         | shared memory  | shared memory  |
//! | `am_long_strided`     | Long Strided | kernel         | strided scatter|
//! | `am_long_vectored`    | Long Vectored| kernel         | extent scatter |
//! | `am_medium_get`       | Medium get   | remote memory  | kernel stream  |
//! | `am_long_get`         | Long get     | remote memory  | local memory   |
//!
//! Every non-async request elicits exactly one reply at the destination;
//! `wait_replies(n)` blocks until `n` outstanding replies have arrived
//! ("Kernels can therefore send several messages and then collectively wait
//! for the same number of replies").

use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Duration;

use crate::am::engine::{barrier_op, BarrierState, ReceivedMedium, ReplyState};
use crate::am::handlers::HandlerTable;
use crate::am::header::{AmMessage, Descriptor};
use crate::am::types::{handler_ids, AmFlags, AmType};
use crate::config::{ApiProfile, ChunkPolicy, ClusterSpec};
use crate::error::{Error, Result};
use crate::galapagos::packet::Packet;
use crate::galapagos::router::RouterMsg;
use crate::memory::Segment;

pub use crate::am::engine::ReceivedMedium as Medium;

/// Default timeout for blocking waits. Generous: the Jacobi benchmarks keep
/// thousands of AMs in flight over loopback TCP.
pub const DEFAULT_TIMEOUT: Duration = Duration::from_secs(120);

/// Receipt returned by send operations: the number of AMs actually emitted
/// (> 1 when the chunking extension split an oversized payload), which is
/// also the number of replies the operation will generate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SendReceipt {
    pub messages: u64,
}

/// The per-kernel API handle. Obtained from
/// [`ShoalCluster`](crate::shoal_node::cluster::ShoalCluster); moved into the
/// kernel function's thread.
pub struct ShoalKernel {
    pub(crate) id: u16,
    pub(crate) spec: Arc<ClusterSpec>,
    pub(crate) router_tx: std::sync::mpsc::Sender<RouterMsg>,
    pub(crate) segment: Segment,
    pub(crate) replies: Arc<ReplyState>,
    pub(crate) barrier_state: Arc<BarrierState>,
    pub(crate) handlers: Arc<HandlerTable>,
    pub(crate) medium_rx: Receiver<ReceivedMedium>,
    /// Replies consumed by previous `wait_replies` calls.
    consumed: u64,
    /// Barrier epoch counter (local).
    epoch: u64,
    token: u32,
    pub timeout: Duration,
}

impl ShoalKernel {
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        id: u16,
        spec: Arc<ClusterSpec>,
        router_tx: std::sync::mpsc::Sender<RouterMsg>,
        segment: Segment,
        replies: Arc<ReplyState>,
        barrier_state: Arc<BarrierState>,
        handlers: Arc<HandlerTable>,
        medium_rx: Receiver<ReceivedMedium>,
    ) -> ShoalKernel {
        ShoalKernel {
            id,
            spec,
            router_tx,
            segment,
            replies,
            barrier_state,
            handlers,
            medium_rx,
            consumed: 0,
            epoch: 0,
            token: 0,
            timeout: DEFAULT_TIMEOUT,
        }
    }

    /// This kernel's globally unique id.
    pub fn id(&self) -> u16 {
        self.id
    }

    /// Number of kernels in the cluster.
    pub fn kernel_count(&self) -> usize {
        self.spec.kernel_count()
    }

    /// This kernel's partition of the global address space (local access —
    /// the cheap side of the PGAS local/remote distinction).
    pub fn mem(&self) -> &Segment {
        &self.segment
    }

    /// The cluster description.
    pub fn cluster(&self) -> &ClusterSpec {
        &self.spec
    }

    fn profile(&self) -> &ApiProfile {
        &self.spec.profile
    }

    fn next_token(&mut self) -> u32 {
        self.token = self.token.wrapping_add(1);
        self.token
    }

    fn send_msg(&self, msg: &AmMessage) -> Result<()> {
        let bytes = msg.encode()?;
        let pkt = Packet::new(msg.dst, msg.src, bytes)?;
        self.router_tx
            .send(RouterMsg::FromKernel(pkt))
            .map_err(|_| Error::Disconnected("router"))
    }

    // -- Short ---------------------------------------------------------------

    /// Send a Short AM (signaling; no payload). Returns after local emit.
    pub fn am_short(&mut self, dst: u16, handler: u8, args: &[u64]) -> Result<SendReceipt> {
        self.am_short_flags(dst, handler, args, AmFlags::new())
    }

    /// Asynchronous Short AM — no reply will be generated.
    pub fn am_short_async(&mut self, dst: u16, handler: u8, args: &[u64]) -> Result<SendReceipt> {
        self.am_short_flags(dst, handler, args, AmFlags::new().with(AmFlags::ASYNC))
    }

    fn am_short_flags(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        flags: AmFlags,
    ) -> Result<SendReceipt> {
        if !self.profile().short {
            return Err(Error::ProfileViolation("short"));
        }
        self.spec.kernel(dst)?;
        let token = self.next_token();
        self.send_msg(&AmMessage {
            am_type: AmType::Short,
            flags,
            src: self.id,
            dst,
            handler,
            token,
            args: args.to_vec(),
            desc: Descriptor::None,
            payload: vec![],
        })?;
        Ok(SendReceipt { messages: if flags.is_async() { 0 } else { 1 } })
    }

    // -- Medium ---------------------------------------------------------------

    /// Medium FIFO put: payload from this kernel to the destination kernel's
    /// stream ("point-to-point communication for one kernel to send data
    /// directly to another kernel").
    pub fn am_medium(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
    ) -> Result<SendReceipt> {
        self.medium_impl(dst, handler, args, payload.to_vec(), AmFlags::new().with(AmFlags::FIFO))
    }

    /// Asynchronous Medium FIFO put.
    pub fn am_medium_async(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
    ) -> Result<SendReceipt> {
        self.medium_impl(
            dst,
            handler,
            args,
            payload.to_vec(),
            AmFlags::new().with(AmFlags::FIFO).with(AmFlags::ASYNC),
        )
    }

    /// Medium put whose payload the runtime reads from this kernel's memory
    /// partition (`src_offset`, `len`) — the non-FIFO variant of §III-A.
    pub fn am_medium_from_mem(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        src_offset: u64,
        len: usize,
    ) -> Result<SendReceipt> {
        let payload = self.segment.read(src_offset, len)?;
        self.medium_impl(dst, handler, args, payload, AmFlags::new())
    }

    fn medium_impl(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: Vec<u8>,
        flags: AmFlags,
    ) -> Result<SendReceipt> {
        if !self.profile().medium {
            return Err(Error::ProfileViolation("medium"));
        }
        self.spec.kernel(dst)?;
        let token = self.next_token();
        let msg = AmMessage {
            am_type: AmType::Medium,
            flags,
            src: self.id,
            dst,
            handler,
            token,
            args: args.to_vec(),
            desc: Descriptor::None,
            payload,
        };
        if msg.payload.len() > msg.max_payload_for() {
            // Medium payloads are a kernel-stream datum; chunking would change
            // message boundaries, so it is always an error (the Jacobi halo
            // exchange failure mode of §IV-C1).
            return Err(Error::AmTooLarge {
                payload: msg.payload.len(),
                limit: msg.max_payload_for(),
            });
        }
        self.send_msg(&msg)?;
        Ok(SendReceipt { messages: if flags.is_async() { 0 } else { 1 } })
    }

    /// Medium get: bring `len` bytes at `src_addr` in the destination
    /// kernel's partition back to this kernel's stream. The data arrives as
    /// a [`ReceivedMedium`] and counts as one reply per emitted chunk.
    pub fn am_medium_get(
        &mut self,
        dst: u16,
        handler: u8,
        src_addr: u64,
        len: usize,
    ) -> Result<SendReceipt> {
        if !self.profile().medium || !self.profile().gets {
            return Err(Error::ProfileViolation("medium get"));
        }
        self.spec.kernel(dst)?;
        let probe = AmMessage {
            am_type: AmType::Medium,
            flags: AmFlags::new().with(AmFlags::GET),
            src: self.id,
            dst,
            handler,
            token: 0,
            args: vec![0],
            desc: Descriptor::MediumGet { src_addr, len: 0 },
            payload: vec![],
        };
        let max = probe.max_payload_for();
        let chunks = self.chunk_ranges(len, max)?;
        let n = chunks.len() as u64;
        for (off, clen) in chunks {
            let token = self.next_token();
            self.send_msg(&AmMessage {
                am_type: AmType::Medium,
                flags: AmFlags::new().with(AmFlags::GET),
                src: self.id,
                dst,
                handler,
                token,
                // Final arg carries the chunk's byte offset so the receiver
                // can reassemble multi-chunk gets.
                args: vec![off],
                desc: Descriptor::MediumGet { src_addr: src_addr + off, len: clen as u32 },
                payload: vec![],
            })?;
        }
        Ok(SendReceipt { messages: n })
    }

    // -- Long -----------------------------------------------------------------

    /// Long FIFO put: payload from this kernel, written into the destination
    /// kernel's partition at `dst_addr`.
    pub fn am_long(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
        dst_addr: u64,
    ) -> Result<SendReceipt> {
        self.long_impl(dst, handler, args, payload, dst_addr, AmFlags::new().with(AmFlags::FIFO))
    }

    /// Asynchronous Long FIFO put.
    pub fn am_long_async(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
        dst_addr: u64,
    ) -> Result<SendReceipt> {
        self.long_impl(
            dst,
            handler,
            args,
            payload,
            dst_addr,
            AmFlags::new().with(AmFlags::FIFO).with(AmFlags::ASYNC),
        )
    }

    /// Long put whose payload the runtime reads from this kernel's partition.
    pub fn am_long_from_mem(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        src_offset: u64,
        len: usize,
        dst_addr: u64,
    ) -> Result<SendReceipt> {
        let payload = self.segment.read(src_offset, len)?;
        self.long_impl(dst, handler, args, &payload, dst_addr, AmFlags::new())
    }

    fn long_impl(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
        dst_addr: u64,
        flags: AmFlags,
    ) -> Result<SendReceipt> {
        if !self.profile().long {
            return Err(Error::ProfileViolation("long"));
        }
        self.spec.kernel(dst)?;
        let probe = AmMessage {
            am_type: AmType::Long,
            flags,
            src: self.id,
            dst,
            handler,
            token: 0,
            args: args.to_vec(),
            desc: Descriptor::Long { dst_addr },
            payload: vec![],
        };
        let max = probe.max_payload_for();
        let chunks = self.chunk_ranges(payload.len(), max)?;
        let n = chunks.len() as u64;
        for (off, clen) in chunks {
            let token = self.next_token();
            self.send_msg(&AmMessage {
                am_type: AmType::Long,
                flags,
                src: self.id,
                dst,
                handler,
                token,
                args: args.to_vec(),
                desc: Descriptor::Long { dst_addr: dst_addr + off },
                payload: payload[off as usize..off as usize + clen].to_vec(),
            })?;
        }
        Ok(SendReceipt { messages: if flags.is_async() { 0 } else { n } })
    }

    /// Long get: read `len` bytes at `src_addr` in the destination kernel's
    /// partition; the reply writes them at `reply_addr` in *this* kernel's
    /// partition. Completion = `wait_replies(receipt.messages)`.
    pub fn am_long_get(
        &mut self,
        dst: u16,
        handler: u8,
        src_addr: u64,
        len: usize,
        reply_addr: u64,
    ) -> Result<SendReceipt> {
        if !self.profile().long || !self.profile().gets {
            return Err(Error::ProfileViolation("long get"));
        }
        self.spec.kernel(dst)?;
        let probe = AmMessage {
            am_type: AmType::Long,
            flags: AmFlags::new().with(AmFlags::REPLY),
            src: dst,
            dst: self.id,
            handler,
            token: 0,
            args: vec![],
            desc: Descriptor::Long { dst_addr: reply_addr },
            payload: vec![],
        };
        let max = probe.max_payload_for();
        let chunks = self.chunk_ranges(len, max)?;
        let n = chunks.len() as u64;
        for (off, clen) in chunks {
            let token = self.next_token();
            self.send_msg(&AmMessage {
                am_type: AmType::Long,
                flags: AmFlags::new().with(AmFlags::GET),
                src: self.id,
                dst,
                handler,
                token,
                args: vec![],
                desc: Descriptor::LongGet {
                    src_addr: src_addr + off,
                    len: clen as u32,
                    reply_addr: reply_addr + off,
                },
                payload: vec![],
            })?;
        }
        Ok(SendReceipt { messages: n })
    }

    /// Strided Long put: block `i` of `block_len` bytes lands at
    /// `dst_addr + i*stride` in the destination's partition.
    pub fn am_long_strided(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
        dst_addr: u64,
        stride: u32,
        block_len: u32,
    ) -> Result<SendReceipt> {
        if !self.profile().strided {
            return Err(Error::ProfileViolation("strided"));
        }
        self.spec.kernel(dst)?;
        if block_len == 0 || payload.len() % block_len as usize != 0 {
            return Err(Error::BadDescriptor(format!(
                "strided payload {} not a multiple of block_len {block_len}",
                payload.len()
            )));
        }
        let nblocks = (payload.len() / block_len as usize) as u32;
        let token = self.next_token();
        let msg = AmMessage {
            am_type: AmType::LongStrided,
            flags: AmFlags::new().with(AmFlags::FIFO),
            src: self.id,
            dst,
            handler,
            token,
            args: args.to_vec(),
            desc: Descriptor::Strided { dst_addr, stride, block_len, nblocks },
            payload: payload.to_vec(),
        };
        if msg.payload.len() > msg.max_payload_for() {
            return Err(Error::AmTooLarge {
                payload: msg.payload.len(),
                limit: msg.max_payload_for(),
            });
        }
        self.send_msg(&msg)?;
        Ok(SendReceipt { messages: 1 })
    }

    /// Vectored Long put: payload split over explicit (addr, len) extents.
    pub fn am_long_vectored(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
        entries: &[(u64, u32)],
    ) -> Result<SendReceipt> {
        if !self.profile().vectored {
            return Err(Error::ProfileViolation("vectored"));
        }
        self.spec.kernel(dst)?;
        let token = self.next_token();
        let msg = AmMessage {
            am_type: AmType::LongVectored,
            flags: AmFlags::new().with(AmFlags::FIFO),
            src: self.id,
            dst,
            handler,
            token,
            args: args.to_vec(),
            desc: Descriptor::Vectored { entries: entries.to_vec() },
            payload: payload.to_vec(),
        };
        msg.validate()?;
        if msg.payload.len() > msg.max_payload_for() {
            return Err(Error::AmTooLarge {
                payload: msg.payload.len(),
                limit: msg.max_payload_for(),
            });
        }
        self.send_msg(&msg)?;
        Ok(SendReceipt { messages: 1 })
    }

    // -- completion ------------------------------------------------------------

    /// Block until `n` more replies have arrived (cumulative bookkeeping is
    /// internal; callers sum the `SendReceipt.messages` of the operations
    /// they are waiting on).
    pub fn wait_replies(&mut self, n: u64) -> Result<()> {
        let target = self.consumed + n;
        self.replies.wait_total(target, self.timeout)?;
        self.consumed = target;
        Ok(())
    }

    /// Replies received but not yet consumed by `wait_replies`.
    pub fn pending_replies(&self) -> u64 {
        self.replies.total() - self.consumed
    }

    /// Blocking receive of the next Medium payload.
    pub fn recv_medium(&self) -> Result<ReceivedMedium> {
        self.medium_rx
            .recv_timeout(self.timeout)
            .map_err(|e| match e {
                std::sync::mpsc::RecvTimeoutError::Timeout => Error::Timeout("medium receive"),
                _ => Error::Disconnected("medium stream"),
            })
    }

    /// Non-blocking receive.
    pub fn try_recv_medium(&self) -> Result<Option<ReceivedMedium>> {
        match self.medium_rx.try_recv() {
            Ok(m) => Ok(Some(m)),
            Err(std::sync::mpsc::TryRecvError::Empty) => Ok(None),
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                Err(Error::Disconnected("medium stream"))
            }
        }
    }

    /// Register a user handler (software kernels only, as in the paper).
    pub fn register_handler(
        &self,
        id: u8,
        f: impl Fn(crate::am::handlers::HandlerArgs<'_>) + Send + Sync + 'static,
    ) -> Result<()> {
        if !self.profile().user_handlers {
            return Err(Error::ProfileViolation("user handlers"));
        }
        self.handlers.register(id, Box::new(f))
    }

    // -- barrier ----------------------------------------------------------------

    /// Cluster-wide barrier over Short AMs. The lowest kernel id acts as the
    /// master: it counts ENTER messages and broadcasts RELEASE.
    pub fn barrier(&mut self) -> Result<()> {
        if !self.profile().barrier {
            return Err(Error::ProfileViolation("barrier"));
        }
        self.epoch += 1;
        let epoch = self.epoch;
        let mut ids: Vec<u16> = self.spec.kernels.iter().map(|k| k.id).collect();
        ids.sort_unstable();
        let master = ids[0];
        let n = ids.len() as u64;
        if n == 1 {
            return Ok(());
        }
        if self.id == master {
            self.barrier_state
                .wait_enters(epoch, n - 1, self.timeout)?;
            for &kid in ids.iter().skip(1) {
                self.am_short_async(
                    kid,
                    handler_ids::BARRIER,
                    &[barrier_op::RELEASE, epoch],
                )?;
            }
            Ok(())
        } else {
            self.am_short_async(master, handler_ids::BARRIER, &[barrier_op::ENTER, epoch])?;
            self.barrier_state.wait_release(epoch, self.timeout)
        }
    }

    // -- helpers ----------------------------------------------------------------

    /// Split `len` bytes into per-message ranges obeying the packet cap and
    /// the cluster chunk policy. Returns (offset, len) pairs.
    fn chunk_ranges(&self, len: usize, max: usize) -> Result<Vec<(u64, usize)>> {
        if len <= max {
            return Ok(vec![(0, len)]);
        }
        match self.spec.chunk_policy {
            ChunkPolicy::Reject => Err(Error::AmTooLarge { payload: len, limit: max }),
            ChunkPolicy::Chunked => {
                let mut out = Vec::with_capacity(len.div_ceil(max));
                let mut off = 0usize;
                while off < len {
                    let clen = max.min(len - off);
                    out.push((off as u64, clen));
                    off += clen;
                }
                Ok(out)
            }
        }
    }
}
