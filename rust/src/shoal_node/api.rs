//! `ShoalKernel` — the heterogeneous communication API (paper §III-A).
//!
//! The same function prototypes serve software and hardware kernels; only
//! the runtime behind them differs (handler thread vs. GAScore). Message
//! classes:
//!
//! | call                  | class        | payload source | destination    |
//! |-----------------------|--------------|----------------|----------------|
//! | `am_short`            | Short        | —              | handler only   |
//! | `am_medium`           | Medium FIFO  | kernel         | kernel stream  |
//! | `am_medium_from_mem`  | Medium       | shared memory  | kernel stream  |
//! | `am_long`             | Long FIFO    | kernel         | shared memory  |
//! | `am_long_from_mem`    | Long         | shared memory  | shared memory  |
//! | `am_long_strided`     | Long Strided | kernel         | strided scatter|
//! | `am_long_vectored`    | Long Vectored| kernel         | extent scatter |
//! | `am_medium_get`       | Medium get   | remote memory  | kernel stream  |
//! | `am_long_get`         | Long get     | remote memory  | local memory   |
//!
//! Completion is per operation: every `am_*` send returns an [`AmHandle`]
//! registered in the kernel's completion table (a multi-chunk send returns
//! one handle covering all its chunks). [`wait`](ShoalKernel::wait),
//! [`test`](ShoalKernel::test), [`wait_all`](ShoalKernel::wait_all) and
//! [`wait_any`](ShoalKernel::wait_any) consume handles, which is what lets a
//! kernel overlap independent transfers with compute and attribute failures
//! to the exact operation. The paper's collective counter model ("send
//! several messages and then collectively wait for the same number of
//! replies") survives as the [`wait_replies`](ShoalKernel::wait_replies)
//! shim over the same table — each operation's completion must be consumed
//! exactly once, by a handle wait *or* by `wait_replies`, never both.
//!
//! Collectives ([`bcast`](ShoalKernel::bcast), [`reduce`](ShoalKernel::reduce),
//! [`all_reduce`](ShoalKernel::all_reduce),
//! [`barrier_tree`](ShoalKernel::barrier_tree)) compose many AM hops over a
//! spanning tree into one logical operation; each returns a
//! [`CollectiveHandle`] whose `am` field is an ordinary completion-table
//! handle, so collectives overlap with point-to-point traffic under the same
//! wait primitives. A collective completion counts as one reply in the
//! `wait_replies` shim.

use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Duration;

use crate::am::completion::{AmHandle, CompletionTable};
use crate::am::engine::{barrier_op, BarrierState, ReceivedMedium};
use crate::am::handlers::HandlerTable;
use crate::am::header::{AmMessage, Descriptor};
use crate::am::types::{handler_ids, AmFlags, AmType};
use crate::collectives::{
    decode_f64s, decode_u64s, encode_f64s, encode_u64s, CollDesc, CollectiveHandle,
    CollectiveKind, CollectiveState, Lane, ReduceOp, TreeKind,
};
use crate::config::{ApiProfile, ChunkPolicy, ClusterSpec};
use crate::error::{Error, Result};
use crate::galapagos::packet::Packet;
use crate::galapagos::router::RouterMsg;
use crate::memory::Segment;

pub use crate::am::engine::ReceivedMedium as Medium;

/// Default timeout for blocking waits. Generous: the Jacobi benchmarks keep
/// thousands of AMs in flight over loopback TCP.
pub const DEFAULT_TIMEOUT: Duration = Duration::from_secs(120);

/// The per-kernel API handle. Obtained from
/// [`ShoalCluster`](crate::shoal_node::cluster::ShoalCluster); moved into the
/// kernel function's thread.
pub struct ShoalKernel {
    pub(crate) id: u16,
    pub(crate) spec: Arc<ClusterSpec>,
    pub(crate) router_tx: std::sync::mpsc::Sender<RouterMsg>,
    pub(crate) segment: Segment,
    pub(crate) completion: Arc<CompletionTable>,
    pub(crate) barrier_state: Arc<BarrierState>,
    pub(crate) handlers: Arc<HandlerTable>,
    pub(crate) collective: Arc<CollectiveState>,
    pub(crate) medium_rx: Receiver<ReceivedMedium>,
    /// Replies consumed by previous waits (`wait_replies` shim bookkeeping).
    consumed: u64,
    /// Barrier epoch counter (local).
    epoch: u64,
    /// Collective sequence counter (local; every kernel issues collectives
    /// in the same cluster-wide order, so counters agree).
    coll_seq: u64,
    pub timeout: Duration,
}

impl ShoalKernel {
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        id: u16,
        spec: Arc<ClusterSpec>,
        router_tx: std::sync::mpsc::Sender<RouterMsg>,
        segment: Segment,
        completion: Arc<CompletionTable>,
        barrier_state: Arc<BarrierState>,
        handlers: Arc<HandlerTable>,
        collective: Arc<CollectiveState>,
        medium_rx: Receiver<ReceivedMedium>,
    ) -> ShoalKernel {
        ShoalKernel {
            id,
            spec,
            router_tx,
            segment,
            completion,
            barrier_state,
            handlers,
            collective,
            medium_rx,
            consumed: 0,
            epoch: 0,
            coll_seq: 0,
            timeout: DEFAULT_TIMEOUT,
        }
    }

    /// This kernel's globally unique id.
    pub fn id(&self) -> u16 {
        self.id
    }

    /// Number of kernels in the cluster.
    pub fn kernel_count(&self) -> usize {
        self.spec.kernel_count()
    }

    /// This kernel's partition of the global address space (local access —
    /// the cheap side of the PGAS local/remote distinction).
    pub fn mem(&self) -> &Segment {
        &self.segment
    }

    /// The cluster description.
    pub fn cluster(&self) -> &ClusterSpec {
        &self.spec
    }

    fn profile(&self) -> &ApiProfile {
        &self.spec.profile
    }

    fn send_msg(&self, msg: &AmMessage) -> Result<()> {
        let bytes = msg.encode()?;
        let pkt = Packet::new(msg.dst, msg.src, bytes)?;
        self.router_tx
            .send(RouterMsg::FromKernel(pkt))
            .map_err(|_| Error::Disconnected("router"))
    }

    /// Stamp one chunk's token + HANDLE flag onto `msg` and send it. A send
    /// failure propagates *into the handle*: the operation transitions to
    /// failed (the reason surfaces as [`Error::OperationFailed`] at
    /// `wait`/`test`) and `false` tells chunk loops to stop early — the
    /// `am_*` call still returns the handle, so the failure is attributed to
    /// the exact operation rather than lost in a batch.
    fn send_tracked(&self, h: AmHandle, msg: &mut AmMessage) -> bool {
        msg.token = self.completion.bind_token(h);
        msg.flags = msg.flags.with(AmFlags::HANDLE);
        match self.send_msg(msg) {
            Ok(()) => true,
            Err(e) => {
                log::warn!("kernel {}: send failed; failing its handle: {e}", self.id);
                self.completion.fail(h, &format!("send failed: {e}"));
                false
            }
        }
    }

    // -- Short ---------------------------------------------------------------

    /// Send a Short AM (signaling; no payload). Returns after local emit.
    pub fn am_short(&mut self, dst: u16, handler: u8, args: &[u64]) -> Result<AmHandle> {
        self.am_short_flags(dst, handler, args, AmFlags::new())
    }

    /// Asynchronous Short AM — no reply will be generated; the returned
    /// handle is already complete.
    pub fn am_short_async(&mut self, dst: u16, handler: u8, args: &[u64]) -> Result<AmHandle> {
        self.am_short_flags(dst, handler, args, AmFlags::new().with(AmFlags::ASYNC))
    }

    fn am_short_flags(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        flags: AmFlags,
    ) -> Result<AmHandle> {
        if !self.profile().short {
            return Err(Error::ProfileViolation("short"));
        }
        self.spec.kernel(dst)?;
        let mut msg = AmMessage {
            am_type: AmType::Short,
            flags,
            src: self.id,
            dst,
            handler,
            token: 0,
            args: args.to_vec(),
            desc: Descriptor::None,
            payload: vec![],
        };
        if flags.is_async() {
            self.send_msg(&msg)?;
            return Ok(AmHandle::completed());
        }
        let h = self.completion.create(1);
        self.send_tracked(h, &mut msg);
        Ok(h)
    }

    // -- Medium ---------------------------------------------------------------

    /// Medium FIFO put: payload from this kernel to the destination kernel's
    /// stream ("point-to-point communication for one kernel to send data
    /// directly to another kernel").
    pub fn am_medium(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
    ) -> Result<AmHandle> {
        self.medium_impl(dst, handler, args, payload.to_vec(), AmFlags::new().with(AmFlags::FIFO))
    }

    /// Asynchronous Medium FIFO put.
    pub fn am_medium_async(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
    ) -> Result<AmHandle> {
        self.medium_impl(
            dst,
            handler,
            args,
            payload.to_vec(),
            AmFlags::new().with(AmFlags::FIFO).with(AmFlags::ASYNC),
        )
    }

    /// Medium put whose payload the runtime reads from this kernel's memory
    /// partition (`src_offset`, `len`) — the non-FIFO variant of §III-A.
    pub fn am_medium_from_mem(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        src_offset: u64,
        len: usize,
    ) -> Result<AmHandle> {
        let payload = self.segment.read(src_offset, len)?;
        self.medium_impl(dst, handler, args, payload, AmFlags::new())
    }

    fn medium_impl(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: Vec<u8>,
        flags: AmFlags,
    ) -> Result<AmHandle> {
        if !self.profile().medium {
            return Err(Error::ProfileViolation("medium"));
        }
        self.spec.kernel(dst)?;
        let mut msg = AmMessage {
            am_type: AmType::Medium,
            flags,
            src: self.id,
            dst,
            handler,
            token: 0,
            args: args.to_vec(),
            desc: Descriptor::None,
            payload,
        };
        if msg.payload.len() > msg.max_payload_for() {
            // Medium payloads are a kernel-stream datum; chunking would change
            // message boundaries, so it is always an error (the Jacobi halo
            // exchange failure mode of §IV-C1).
            return Err(Error::AmTooLarge {
                payload: msg.payload.len(),
                limit: msg.max_payload_for(),
            });
        }
        if flags.is_async() {
            self.send_msg(&msg)?;
            return Ok(AmHandle::completed());
        }
        let h = self.completion.create(1);
        self.send_tracked(h, &mut msg);
        Ok(h)
    }

    /// Medium get: bring `len` bytes at `src_addr` in the destination
    /// kernel's partition back to this kernel's stream. The data arrives as
    /// a [`ReceivedMedium`] per emitted chunk; the handle completes when
    /// every chunk's data reply has arrived.
    pub fn am_medium_get(
        &mut self,
        dst: u16,
        handler: u8,
        src_addr: u64,
        len: usize,
    ) -> Result<AmHandle> {
        if !self.profile().medium || !self.profile().gets {
            return Err(Error::ProfileViolation("medium get"));
        }
        self.spec.kernel(dst)?;
        let probe = AmMessage {
            am_type: AmType::Medium,
            flags: AmFlags::new().with(AmFlags::GET),
            src: self.id,
            dst,
            handler,
            token: 0,
            args: vec![0],
            desc: Descriptor::MediumGet { src_addr, len: 0 },
            payload: vec![],
        };
        let max = probe.max_payload_for();
        let chunks = self.chunk_ranges(len, max)?;
        // Validate every chunk's address arithmetic *before* registering the
        // operation, so an overflow cannot abandon a half-issued handle.
        let descs = chunks
            .iter()
            .map(|&(off, clen)| {
                Ok((off, Descriptor::MediumGet {
                    src_addr: checked_offset(src_addr, off)?,
                    len: clen as u32,
                }))
            })
            .collect::<Result<Vec<_>>>()?;
        let h = self.completion.create(descs.len() as u64);
        for (off, desc) in descs {
            let mut msg = AmMessage {
                am_type: AmType::Medium,
                flags: AmFlags::new().with(AmFlags::GET),
                src: self.id,
                dst,
                handler,
                token: 0,
                // Final arg carries the chunk's byte offset so the receiver
                // can reassemble multi-chunk gets.
                args: vec![off],
                desc,
                payload: vec![],
            };
            if !self.send_tracked(h, &mut msg) {
                break;
            }
        }
        Ok(h)
    }

    // -- Long -----------------------------------------------------------------

    /// Long FIFO put: payload from this kernel, written into the destination
    /// kernel's partition at `dst_addr`.
    pub fn am_long(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
        dst_addr: u64,
    ) -> Result<AmHandle> {
        self.long_impl(dst, handler, args, payload, dst_addr, AmFlags::new().with(AmFlags::FIFO))
    }

    /// Asynchronous Long FIFO put.
    pub fn am_long_async(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
        dst_addr: u64,
    ) -> Result<AmHandle> {
        self.long_impl(
            dst,
            handler,
            args,
            payload,
            dst_addr,
            AmFlags::new().with(AmFlags::FIFO).with(AmFlags::ASYNC),
        )
    }

    /// Long put whose payload the runtime reads from this kernel's partition.
    pub fn am_long_from_mem(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        src_offset: u64,
        len: usize,
        dst_addr: u64,
    ) -> Result<AmHandle> {
        let payload = self.segment.read(src_offset, len)?;
        self.long_impl(dst, handler, args, &payload, dst_addr, AmFlags::new())
    }

    fn long_impl(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
        dst_addr: u64,
        flags: AmFlags,
    ) -> Result<AmHandle> {
        if !self.profile().long {
            return Err(Error::ProfileViolation("long"));
        }
        self.spec.kernel(dst)?;
        let probe = AmMessage {
            am_type: AmType::Long,
            flags,
            src: self.id,
            dst,
            handler,
            token: 0,
            args: args.to_vec(),
            desc: Descriptor::Long { dst_addr },
            payload: vec![],
        };
        let max = probe.max_payload_for();
        let chunks = self.chunk_ranges(payload.len(), max)?;
        // Address arithmetic validated before the operation is registered.
        let descs = chunks
            .iter()
            .map(|&(off, clen)| {
                Ok((off, clen, Descriptor::Long { dst_addr: checked_offset(dst_addr, off)? }))
            })
            .collect::<Result<Vec<_>>>()?;
        let h = if flags.is_async() {
            AmHandle::completed()
        } else {
            self.completion.create(descs.len() as u64)
        };
        for (off, clen, desc) in descs {
            let mut msg = AmMessage {
                am_type: AmType::Long,
                flags,
                src: self.id,
                dst,
                handler,
                token: 0,
                args: args.to_vec(),
                desc,
                payload: payload[off as usize..off as usize + clen].to_vec(),
            };
            if flags.is_async() {
                self.send_msg(&msg)?;
            } else if !self.send_tracked(h, &mut msg) {
                break;
            }
        }
        Ok(h)
    }

    /// Long get: read `len` bytes at `src_addr` in the destination kernel's
    /// partition; the reply writes them at `reply_addr` in *this* kernel's
    /// partition. Completion = `wait(handle)` (or the `wait_replies` shim).
    pub fn am_long_get(
        &mut self,
        dst: u16,
        handler: u8,
        src_addr: u64,
        len: usize,
        reply_addr: u64,
    ) -> Result<AmHandle> {
        if !self.profile().long || !self.profile().gets {
            return Err(Error::ProfileViolation("long get"));
        }
        self.spec.kernel(dst)?;
        let probe = AmMessage {
            am_type: AmType::Long,
            flags: AmFlags::new().with(AmFlags::REPLY),
            src: dst,
            dst: self.id,
            handler,
            token: 0,
            args: vec![],
            desc: Descriptor::Long { dst_addr: reply_addr },
            payload: vec![],
        };
        let max = probe.max_payload_for();
        let chunks = self.chunk_ranges(len, max)?;
        // Address arithmetic validated before the operation is registered.
        let descs = chunks
            .iter()
            .map(|&(off, clen)| {
                Ok(Descriptor::LongGet {
                    src_addr: checked_offset(src_addr, off)?,
                    len: clen as u32,
                    reply_addr: checked_offset(reply_addr, off)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let h = self.completion.create(descs.len() as u64);
        for desc in descs {
            let mut msg = AmMessage {
                am_type: AmType::Long,
                flags: AmFlags::new().with(AmFlags::GET),
                src: self.id,
                dst,
                handler,
                token: 0,
                args: vec![],
                desc,
                payload: vec![],
            };
            if !self.send_tracked(h, &mut msg) {
                break;
            }
        }
        Ok(h)
    }

    /// Strided Long put: block `i` of `block_len` bytes lands at
    /// `dst_addr + i*stride` in the destination's partition.
    pub fn am_long_strided(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
        dst_addr: u64,
        stride: u32,
        block_len: u32,
    ) -> Result<AmHandle> {
        if !self.profile().strided {
            return Err(Error::ProfileViolation("strided"));
        }
        self.spec.kernel(dst)?;
        if block_len == 0 || payload.len() % block_len as usize != 0 {
            return Err(Error::BadDescriptor(format!(
                "strided payload {} not a multiple of block_len {block_len}",
                payload.len()
            )));
        }
        let nblocks = (payload.len() / block_len as usize) as u32;
        let mut msg = AmMessage {
            am_type: AmType::LongStrided,
            flags: AmFlags::new().with(AmFlags::FIFO),
            src: self.id,
            dst,
            handler,
            token: 0,
            args: args.to_vec(),
            desc: Descriptor::Strided { dst_addr, stride, block_len, nblocks },
            payload: payload.to_vec(),
        };
        if msg.payload.len() > msg.max_payload_for() {
            return Err(Error::AmTooLarge {
                payload: msg.payload.len(),
                limit: msg.max_payload_for(),
            });
        }
        let h = self.completion.create(1);
        self.send_tracked(h, &mut msg);
        Ok(h)
    }

    /// Vectored Long put: payload split over explicit (addr, len) extents.
    pub fn am_long_vectored(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
        entries: &[(u64, u32)],
    ) -> Result<AmHandle> {
        if !self.profile().vectored {
            return Err(Error::ProfileViolation("vectored"));
        }
        self.spec.kernel(dst)?;
        let mut msg = AmMessage {
            am_type: AmType::LongVectored,
            flags: AmFlags::new().with(AmFlags::FIFO),
            src: self.id,
            dst,
            handler,
            token: 0,
            args: args.to_vec(),
            desc: Descriptor::Vectored { entries: entries.to_vec() },
            payload: payload.to_vec(),
        };
        msg.validate()?;
        if msg.payload.len() > msg.max_payload_for() {
            return Err(Error::AmTooLarge {
                payload: msg.payload.len(),
                limit: msg.max_payload_for(),
            });
        }
        let h = self.completion.create(1);
        self.send_tracked(h, &mut msg);
        Ok(h)
    }

    // -- completion ------------------------------------------------------------

    /// Block until `h` completes, consuming it. A failed send surfaces its
    /// reason as [`Error::OperationFailed`]; a timeout leaves the operation
    /// outstanding. Waiting an already-consumed handle succeeds without
    /// double-crediting the `wait_replies` bookkeeping.
    pub fn wait(&mut self, h: AmHandle) -> Result<()> {
        if self.completion.wait(h, self.timeout)? {
            self.consumed += h.messages;
        }
        Ok(())
    }

    /// Nonblocking completion probe: `Ok(true)` consumes the handle,
    /// `Ok(false)` means still in flight, `Err` surfaces a failed send.
    pub fn test(&mut self, h: AmHandle) -> Result<bool> {
        match self.completion.test(h)? {
            None => Ok(false),
            Some(first) => {
                if first {
                    self.consumed += h.messages;
                }
                Ok(true)
            }
        }
    }

    /// Block until every handle in `hs` completes (consuming all of them) —
    /// the fence after a batch of overlapped transfers. Handles already
    /// consumed (e.g. by an earlier `wait_any`) are skipped harmlessly. An
    /// empty slice is a vacuous fence: it returns `Ok(())` immediately
    /// (every one of zero handles is complete), unlike
    /// [`wait_any`](ShoalKernel::wait_any) for which emptiness is an error.
    pub fn wait_all(&mut self, hs: &[AmHandle]) -> Result<()> {
        let deadline = std::time::Instant::now() + self.timeout;
        for h in hs {
            let now = std::time::Instant::now();
            let left = if now >= deadline { Duration::ZERO } else { deadline - now };
            if self.completion.wait(*h, left)? {
                self.consumed += h.messages;
            }
        }
        Ok(())
    }

    /// Block until *any* handle in `hs` completes; returns the index of the
    /// completed handle (consuming only that one). An empty slice returns
    /// [`Error::EmptyWaitSet`] immediately — nothing could ever complete.
    pub fn wait_any(&mut self, hs: &[AmHandle]) -> Result<usize> {
        let (i, first) = self.completion.wait_any(hs, self.timeout)?;
        if first {
            self.consumed += hs[i].messages;
        }
        Ok(i)
    }

    /// Block until `n` more replies have arrived — the paper's collective
    /// completion model, retained as a shim over the completion table
    /// (callers sum the `AmHandle::messages` of the operations they wait
    /// on). Do not mix with handle waits *for the same operations*.
    pub fn wait_replies(&mut self, n: u64) -> Result<()> {
        let target = self.consumed + n;
        self.completion.wait_total(target, self.timeout)?;
        self.consumed = target;
        Ok(())
    }

    /// Replies received but not yet consumed by any wait. Saturates at zero:
    /// double-consuming a handle (wait/test after it already settled) can
    /// push the consumed count past the resolved count.
    pub fn pending_replies(&self) -> u64 {
        self.completion.resolved_total().saturating_sub(self.consumed)
    }

    /// Blocking receive of the next Medium payload.
    pub fn recv_medium(&self) -> Result<ReceivedMedium> {
        self.medium_rx
            .recv_timeout(self.timeout)
            .map_err(|e| match e {
                std::sync::mpsc::RecvTimeoutError::Timeout => Error::Timeout("medium receive"),
                _ => Error::Disconnected("medium stream"),
            })
    }

    /// Non-blocking receive.
    pub fn try_recv_medium(&self) -> Result<Option<ReceivedMedium>> {
        match self.medium_rx.try_recv() {
            Ok(m) => Ok(Some(m)),
            Err(std::sync::mpsc::TryRecvError::Empty) => Ok(None),
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                Err(Error::Disconnected("medium stream"))
            }
        }
    }

    /// Register a user handler (software kernels only, as in the paper).
    pub fn register_handler(
        &self,
        id: u8,
        f: impl Fn(crate::am::handlers::HandlerArgs<'_>) + Send + Sync + 'static,
    ) -> Result<()> {
        if !self.profile().user_handlers {
            return Err(Error::ProfileViolation("user handlers"));
        }
        self.handlers.register(id, Box::new(f))
    }

    // -- barrier ----------------------------------------------------------------

    /// Cluster-wide barrier over Short AMs. The lowest kernel id acts as the
    /// master: it counts ENTER messages and broadcasts RELEASE.
    pub fn barrier(&mut self) -> Result<()> {
        if !self.profile().barrier {
            return Err(Error::ProfileViolation("barrier"));
        }
        self.epoch += 1;
        let epoch = self.epoch;
        let mut ids: Vec<u16> = self.spec.kernels.iter().map(|k| k.id).collect();
        ids.sort_unstable();
        let master = ids[0];
        let n = ids.len() as u64;
        if n == 1 {
            return Ok(());
        }
        if self.id == master {
            // Seed membership so a timeout names never-arrived kernels too.
            self.barrier_state.note_members(&ids[1..]);
            self.barrier_state
                .wait_enters(epoch, n - 1, self.timeout)?;
            for &kid in ids.iter().skip(1) {
                self.am_short_async(
                    kid,
                    handler_ids::BARRIER,
                    &[barrier_op::RELEASE, epoch],
                )?;
            }
            Ok(())
        } else {
            self.am_short_async(master, handler_ids::BARRIER, &[barrier_op::ENTER, epoch])?;
            self.barrier_state.wait_release(epoch, self.timeout)
        }
    }

    // -- collectives ------------------------------------------------------------

    /// Lowest kernel id in the cluster — the implicit root of rootless
    /// collectives (`all_reduce`, `barrier_tree`), mirroring the counter
    /// barrier's master-selection rule.
    fn lowest_kernel(&self) -> u16 {
        self.spec.kernels.iter().map(|k| k.id).min().unwrap_or(self.id)
    }

    fn collective_impl(
        &mut self,
        kind: CollectiveKind,
        op: ReduceOp,
        lane: Lane,
        root: u16,
        data: &[u8],
    ) -> Result<CollectiveHandle> {
        // Collectives ride Medium AMs and generalize the barrier; both
        // capabilities must be in the active profile.
        if !self.profile().medium || !self.profile().barrier {
            return Err(Error::ProfileViolation("collectives"));
        }
        self.spec.kernel(root)?;
        if kind != CollectiveKind::Bcast && data.len() % 8 != 0 {
            return Err(Error::BadDescriptor(format!(
                "reduction payload of {} bytes is not a whole number of 8-byte lanes",
                data.len()
            )));
        }
        // Every tree hop carries the payload in one Medium AM; no chunking.
        let probe = AmMessage {
            am_type: AmType::Medium,
            flags: AmFlags::new().with(AmFlags::ASYNC),
            src: self.id,
            dst: self.id,
            handler: handler_ids::COLLECTIVE,
            token: 0,
            args: vec![0, 0, 0],
            desc: Descriptor::None,
            payload: vec![],
        };
        if data.len() > probe.max_payload_for() {
            return Err(Error::AmTooLarge {
                payload: data.len(),
                limit: probe.max_payload_for(),
            });
        }
        self.coll_seq += 1;
        let seq = self.coll_seq;
        let desc = CollDesc { kind, op, lane, tree: TreeKind::Binomial, root };
        let h = self.completion.create(1);
        let token = self.completion.bind_token(h);
        let ingress = self.collective.begin(seq, desc, data, token)?;
        let mut send_failed = false;
        for m in &ingress.out {
            if let Err(e) = self.send_msg(m) {
                log::warn!("kernel {}: collective send failed: {e}", self.id);
                self.completion.fail(h, &format!("collective send failed: {e}"));
                send_failed = true;
                break;
            }
        }
        // Resolution strictly after the fan went out: a send failure leaves
        // the handle in flight so `fail` above transitions it to failed and
        // the caller's wait surfaces the error instead of a phantom success.
        if !send_failed {
            if let Some(t) = ingress.resolve {
                self.completion.resolve(t);
            }
        }
        Ok(CollectiveHandle { am: h, seq, kind })
    }

    /// Broadcast `data` from `root` down the collective tree. Non-root
    /// callers' `data` is ignored; every kernel's
    /// [`collective_wait`](ShoalKernel::collective_wait) returns the root's
    /// bytes. Nonblocking: the returned handle's `am` composes with
    /// `wait`/`test`/`wait_all`/`wait_any`.
    pub fn bcast(&mut self, root: u16, data: &[u8]) -> Result<CollectiveHandle> {
        self.collective_impl(CollectiveKind::Bcast, ReduceOp::Sum, Lane::U64, root, data)
    }

    /// Element-wise reduction of every kernel's `contribution` up the tree;
    /// the fold materializes at `root` (everyone else's result is empty).
    pub fn reduce(
        &mut self,
        root: u16,
        op: ReduceOp,
        lane: Lane,
        contribution: &[u8],
    ) -> Result<CollectiveHandle> {
        self.collective_impl(CollectiveKind::Reduce, op, lane, root, contribution)
    }

    /// Reduce-then-broadcast: every kernel contributes and every kernel's
    /// `collective_wait` returns the full fold — the primitive that lets
    /// workloads agree on global state (e.g. a convergence residual) in
    /// `O(log n)` hops instead of `n` point-to-point round trips.
    pub fn all_reduce(
        &mut self,
        op: ReduceOp,
        lane: Lane,
        contribution: &[u8],
    ) -> Result<CollectiveHandle> {
        let root = self.lowest_kernel();
        self.collective_impl(CollectiveKind::AllReduce, op, lane, root, contribution)
    }

    /// `all_reduce` over `u64` lanes.
    pub fn all_reduce_u64(&mut self, op: ReduceOp, vals: &[u64]) -> Result<CollectiveHandle> {
        self.all_reduce(op, Lane::U64, &encode_u64s(vals))
    }

    /// `all_reduce` over `f64` lanes.
    pub fn all_reduce_f64(&mut self, op: ReduceOp, vals: &[f64]) -> Result<CollectiveHandle> {
        self.all_reduce(op, Lane::F64, &encode_f64s(vals))
    }

    /// Block until the collective completes and return its result bytes
    /// (root's payload for `bcast`, the fold for `all_reduce` everywhere and
    /// `reduce` at the root, empty otherwise). A timeout is converted into
    /// [`Error::OperationFailed`] naming the straggler kernels — the
    /// collective analogue of the barrier's straggler diagnostic — and fails
    /// the handle so later waits agree. Safe to call after the handle was
    /// already consumed by `wait`/`wait_all` (it just fetches the result).
    pub fn collective_wait(&mut self, ch: CollectiveHandle) -> Result<Vec<u8>> {
        match self.wait(ch.am) {
            Ok(()) => self.collective.take_result(ch.seq),
            Err(Error::Timeout(_)) => {
                let (awaiting, down_from) = self.collective.pending(ch.seq);
                let reason = if !awaiting.is_empty() {
                    // Per-collective straggler naming: the coordinator
                    // ledger says how far each missing subtree ever got,
                    // separating a dead kernel from a merely lagging one.
                    let lag: Vec<String> = awaiting
                        .iter()
                        .map(|&kid| match self.collective.last_contribution(kid) {
                            Some(s) if s > 0 => format!("kernel {kid} (last at #{s})"),
                            _ => format!("kernel {kid} (never contributed)"),
                        })
                        .collect();
                    format!(
                        "collective #{} ({}) timed out: missing contributions from {}",
                        ch.seq,
                        ch.kind.label(),
                        lag.join(", ")
                    )
                } else if let Some(p) = down_from {
                    format!(
                        "collective #{} ({}) timed out: no result from parent kernel {p}",
                        ch.seq,
                        ch.kind.label()
                    )
                } else {
                    format!(
                        "collective #{} ({}) timed out before this kernel's part completed \
                         (cluster view: kernels {:?} had not reached it)",
                        ch.seq,
                        ch.kind.label(),
                        self.collective.ledger_stragglers(ch.seq)
                    )
                };
                self.completion.fail(ch.am, &reason);
                Err(Error::OperationFailed(reason))
            }
            Err(e) => Err(e),
        }
    }

    /// `collective_wait` decoding the result as `u64` lanes.
    pub fn collective_wait_u64(&mut self, ch: CollectiveHandle) -> Result<Vec<u64>> {
        decode_u64s(&self.collective_wait(ch)?)
    }

    /// `collective_wait` decoding the result as `f64` lanes.
    pub fn collective_wait_f64(&mut self, ch: CollectiveHandle) -> Result<Vec<f64>> {
        decode_f64s(&self.collective_wait(ch)?)
    }

    /// Nonblocking collective probe: `Ok(Some(result))` the first time the
    /// collective is observed complete (consuming it), `Ok(None)` while in
    /// flight.
    pub fn collective_test(&mut self, ch: CollectiveHandle) -> Result<Option<Vec<u8>>> {
        if self.test(ch.am)? {
            Ok(Some(self.collective.take_result(ch.seq)?))
        } else {
            Ok(None)
        }
    }

    /// Cluster-wide barrier over the collective tree: an all-reduce with an
    /// empty payload. `O(log n)` critical-path hops versus the counter
    /// barrier's `O(n)` fan at the master — the `barrier()` alternative for
    /// larger clusters.
    pub fn barrier_tree(&mut self) -> Result<()> {
        if !self.profile().barrier {
            return Err(Error::ProfileViolation("barrier"));
        }
        let root = self.lowest_kernel();
        let ch =
            self.collective_impl(CollectiveKind::Barrier, ReduceOp::Sum, Lane::U64, root, &[])?;
        self.collective_wait(ch).map(|_| ())
    }

    // -- helpers ----------------------------------------------------------------

    /// Split `len` bytes into per-message ranges obeying the packet cap and
    /// the cluster chunk policy. Returns (offset, len) pairs.
    fn chunk_ranges(&self, len: usize, max: usize) -> Result<Vec<(u64, usize)>> {
        if len <= max {
            return Ok(vec![(0, len)]);
        }
        match self.spec.chunk_policy {
            ChunkPolicy::Reject => Err(Error::AmTooLarge { payload: len, limit: max }),
            ChunkPolicy::Chunked => {
                let mut out = Vec::with_capacity(len.div_ceil(max));
                let mut off = 0usize;
                while off < len {
                    let clen = max.min(len - off);
                    out.push((off as u64, clen));
                    off += clen;
                }
                Ok(out)
            }
        }
    }
}

/// Chunk address arithmetic with overflow detection — a silent `u64` wrap
/// here would scatter a chunk to the bottom of the destination partition.
fn checked_offset(base: u64, off: u64) -> Result<u64> {
    base.checked_add(off).ok_or_else(|| {
        Error::BadDescriptor(format!("address overflow: {base:#x} + {off:#x} exceeds u64"))
    })
}
