//! `ShoalKernel` — the heterogeneous communication API (paper §III-A).
//!
//! The same function prototypes serve software and hardware kernels; only
//! the runtime behind them differs (handler thread vs. GAScore). Message
//! classes:
//!
//! | call                  | class        | payload source | destination    |
//! |-----------------------|--------------|----------------|----------------|
//! | `am_short`            | Short        | —              | handler only   |
//! | `am_medium`           | Medium FIFO  | kernel         | kernel stream  |
//! | `am_medium_from_mem`  | Medium       | shared memory  | kernel stream  |
//! | `am_long`             | Long FIFO    | kernel         | shared memory  |
//! | `am_long_from_mem`    | Long         | shared memory  | shared memory  |
//! | `am_long_strided`     | Long Strided | kernel         | strided scatter|
//! | `am_long_vectored`    | Long Vectored| kernel         | extent scatter |
//! | `am_medium_get`       | Medium get   | remote memory  | kernel stream  |
//! | `am_long_get`         | Long get     | remote memory  | local memory   |
//! | `am_atomic`           | Atomic       | —              | word RMW       |
//! | `am_accumulate`       | Atomic       | kernel         | element fold   |
//!
//! This is the low-level, handler-carrying tier. For plain PGAS data
//! movement (put/get/atomic against a global address, no handler), prefer
//! the typed one-sided tier: [`rma`](ShoalKernel::rma) returns an
//! [`Rma`](crate::shoal_node::rma::Rma) facade whose
//! [`OpOptions`](crate::shoal_node::rma::OpOptions) replace the
//! `_async`/`_from_mem` variant matrix; it lowers onto exactly these
//! builders.
//!
//! Completion is per operation: every `am_*` send returns an [`AmHandle`]
//! registered in the kernel's completion table (a multi-chunk send returns
//! one handle covering all its chunks). [`wait`](ShoalKernel::wait),
//! [`test`](ShoalKernel::test), [`wait_all`](ShoalKernel::wait_all) and
//! [`wait_any`](ShoalKernel::wait_any) consume handles, which is what lets a
//! kernel overlap independent transfers with compute and attribute failures
//! to the exact operation. The paper's collective counter model ("send
//! several messages and then collectively wait for the same number of
//! replies") survives as the [`wait_replies`](ShoalKernel::wait_replies)
//! shim over the same table — each operation's completion must be consumed
//! exactly once, by a handle wait *or* by `wait_replies`, never both.
//!
//! Sends are zero-copy: every builder encodes its caller's arg and payload
//! slices straight into a pooled wire buffer via
//! [`WireBuilder`](crate::am::wire::WireBuilder) (one copy, caller → wire),
//! and a put/get whose destination is a software kernel on the *same node*
//! skips the wire entirely — the one-sided fast path
//! ([`fastpath`](crate::shoal_node::fastpath)) accesses the target segment
//! directly and resolves the handle at issue time.
//!
//! Collectives ([`bcast`](ShoalKernel::bcast), [`reduce`](ShoalKernel::reduce),
//! [`all_reduce`](ShoalKernel::all_reduce),
//! [`barrier_tree`](ShoalKernel::barrier_tree)) compose many AM hops over a
//! spanning tree into one logical operation; each returns a
//! [`CollectiveHandle`] whose `am` field is an ordinary completion-table
//! handle, so collectives overlap with point-to-point traffic under the same
//! wait primitives. A collective completion counts as one reply in the
//! `wait_replies` shim.

use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Duration;

use crate::am::completion::{AmHandle, CompletionTable};
use crate::am::engine::{barrier_op, execute_atomic, BarrierState, ReceivedMedium};
use crate::am::handlers::HandlerTable;
use crate::am::header::AmMessage;
use crate::am::types::{handler_ids, AmFlags, AmType, AtomicOp};
use crate::am::wire::{WireBuilder, WireDesc};
use crate::collectives::{
    decode_f64s, decode_u64s, encode_f64s, encode_u64s, CollDesc, CollectiveHandle,
    CollectiveKind, CollectiveState, Lane, ReduceOp, TreeKind,
};
use crate::config::{ApiProfile, ChunkPolicy, ClusterSpec};
use crate::error::{Error, Result};
use crate::galapagos::packet::Packet;
use crate::galapagos::router::RouterHandle;
use crate::galapagos::transport::batch::BufPool;
use crate::memory::Segment;
use crate::shoal_node::fastpath::{LocalFastPath, PutDisposition};

pub use crate::am::engine::ReceivedMedium as Medium;

/// Default timeout for blocking waits. Generous: the Jacobi benchmarks keep
/// thousands of AMs in flight over loopback TCP.
pub const DEFAULT_TIMEOUT: Duration = Duration::from_secs(120);

/// The per-kernel API handle. Obtained from
/// [`ShoalCluster`](crate::shoal_node::cluster::ShoalCluster); moved into the
/// kernel function's thread.
pub struct ShoalKernel {
    pub(crate) id: u16,
    /// Node hosting this kernel (intra-node fast-path eligibility check).
    pub(crate) node: u16,
    pub(crate) spec: Arc<ClusterSpec>,
    pub(crate) router: RouterHandle,
    pub(crate) segment: Segment,
    pub(crate) completion: Arc<CompletionTable>,
    pub(crate) barrier_state: Arc<BarrierState>,
    pub(crate) handlers: Arc<HandlerTable>,
    pub(crate) collective: Arc<CollectiveState>,
    pub(crate) medium_rx: Receiver<ReceivedMedium>,
    /// Same-process one-sided fast path registry (`None` on hardware
    /// kernels and when the cluster disables `local_fastpath`).
    pub(crate) fastpath: Option<Arc<LocalFastPath>>,
    /// Wire-encode buffer pool. A successfully sent buffer travels with its
    /// packet (and on local topologies becomes the ingress payload via
    /// `decode_owned` — the datapath's single copy), so the pool only
    /// reclaims encode-failure buffers; the steady-state send cost is one
    /// exact-size allocation.
    wire_pool: BufPool,
    /// When set, the intra-node fast path is skipped for subsequent sends —
    /// [`Rma`](crate::shoal_node::rma::Rma) per-op locality control; also
    /// what the benchmarks' `no_fastpath` placement measures.
    pub(crate) force_wire: bool,
    /// Per-operation chunk policy override ([`Rma`] `OpOptions::chunk`);
    /// `None` defers to the cluster-wide policy.
    pub(crate) chunk_override: Option<ChunkPolicy>,
    /// Replies consumed by previous waits (`wait_replies` shim bookkeeping).
    consumed: u64,
    /// Barrier epoch counter (local).
    epoch: u64,
    /// Collective sequence counter (local; every kernel issues collectives
    /// in the same cluster-wide order, so counters agree).
    coll_seq: u64,
    pub timeout: Duration,
}

impl ShoalKernel {
    // 11 params: the kernel aggregates every per-kernel resource once at
    // launch; callers never see this internal constructor.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        id: u16,
        node: u16,
        spec: Arc<ClusterSpec>,
        router: RouterHandle,
        segment: Segment,
        completion: Arc<CompletionTable>,
        barrier_state: Arc<BarrierState>,
        handlers: Arc<HandlerTable>,
        collective: Arc<CollectiveState>,
        medium_rx: Receiver<ReceivedMedium>,
        fastpath: Option<Arc<LocalFastPath>>,
    ) -> ShoalKernel {
        ShoalKernel {
            id,
            node,
            spec,
            router,
            segment,
            completion,
            barrier_state,
            handlers,
            collective,
            medium_rx,
            fastpath,
            wire_pool: BufPool::default(),
            force_wire: false,
            chunk_override: None,
            consumed: 0,
            epoch: 0,
            coll_seq: 0,
            timeout: DEFAULT_TIMEOUT,
        }
    }

    /// This kernel's globally unique id.
    pub fn id(&self) -> u16 {
        self.id
    }

    /// Number of kernels in the cluster.
    pub fn kernel_count(&self) -> usize {
        self.spec.kernel_count()
    }

    /// This kernel's partition of the global address space (local access —
    /// the cheap side of the PGAS local/remote distinction).
    pub fn mem(&self) -> &Segment {
        &self.segment
    }

    /// The typed one-sided tier: put/get/atomics against a
    /// [`GlobalAddress`](crate::memory::GlobalAddress) with per-operation
    /// [`OpOptions`](crate::shoal_node::rma::OpOptions), lowered entirely
    /// onto the `am_*` builders (wire behavior unchanged). Borrows this
    /// kernel mutably, so `k.rma().put(...)` interleaves freely with raw-AM
    /// calls.
    pub fn rma(&mut self) -> crate::shoal_node::rma::Rma<'_> {
        crate::shoal_node::rma::Rma::new(self)
    }

    /// The cluster description.
    pub fn cluster(&self) -> &ClusterSpec {
        &self.spec
    }

    fn profile(&self) -> &ApiProfile {
        &self.spec.profile
    }

    /// Send an already-materialized message (collective fan hops, which the
    /// collective state machine owns). The `am_*` builders never take this
    /// path — they encode borrowed caller slices via [`Self::send_wire`].
    fn send_msg(&self, msg: &AmMessage) -> Result<()> {
        let bytes = msg.encode()?;
        let pkt = Packet::new(msg.dst, msg.src, bytes)?;
        self.router.from_kernel(pkt)
    }

    /// The zero-copy egress: encode header + args + payload straight from
    /// the caller's slices into a pool-recycled wire buffer and hand it to
    /// the router. One copy, caller → wire; on local topologies the same
    /// allocation is reused as the ingress payload (`decode_owned`), so the
    /// whole datapath stays single-copy.
    fn send_wire(&mut self, wb: &WireBuilder<'_>, payload: &[u8]) -> Result<()> {
        let mut buf = self.wire_pool.acquire();
        if let Err(e) = wb.encode_slice(payload, &mut buf) {
            self.wire_pool.release(buf);
            return Err(e);
        }
        self.dispatch_wire(wb, buf)
    }

    /// `send_wire` with the payload produced by `fill` writing directly into
    /// the wire buffer (the `am_*_from_mem` path: segment → wire, no
    /// intermediate buffer).
    fn send_wire_with(
        &mut self,
        wb: &WireBuilder<'_>,
        payload_len: usize,
        fill: impl FnOnce(&mut [u8]) -> Result<()>,
    ) -> Result<()> {
        let mut buf = self.wire_pool.acquire();
        if let Err(e) = wb.encode_with(payload_len, &mut buf, fill) {
            self.wire_pool.release(buf);
            return Err(e);
        }
        self.dispatch_wire(wb, buf)
    }

    /// Wrap an encoded wire buffer in a middleware packet and hand it to the
    /// router.
    fn dispatch_wire(&self, wb: &WireBuilder<'_>, buf: Vec<u8>) -> Result<()> {
        let pkt = Packet::new(wb.dst, wb.src, buf)?;
        self.router.from_kernel(pkt)
    }

    /// Stamp one chunk's token + HANDLE flag onto `wb`.
    fn track(&self, h: AmHandle, wb: &mut WireBuilder<'_>) {
        wb.token = self.completion.bind_token(h);
        wb.flags = wb.flags.with(AmFlags::HANDLE);
    }

    /// Convert a tracked send's outcome: a failure propagates *into the
    /// handle* (the operation transitions to failed; the reason surfaces as
    /// [`Error::OperationFailed`] at `wait`/`test`) and `false` tells chunk
    /// loops to stop early — the `am_*` call still returns the handle, so
    /// the failure is attributed to the exact operation rather than lost in
    /// a batch.
    fn tracked_outcome(&self, h: AmHandle, sent: Result<()>) -> bool {
        match sent {
            Ok(()) => true,
            Err(e) => {
                log::warn!("kernel {}: send failed; failing its handle: {e}", self.id);
                // A send fenced at issue (dead peer) keeps its structured
                // error so `wait` reports `Error::PeerDead`, not a string.
                if matches!(e, Error::PeerDead { .. }) {
                    self.completion.fail_error(h, &e);
                } else {
                    self.completion.fail(h, &format!("send failed: {e}"));
                }
                false
            }
        }
    }

    /// Tracked send of one chunk from a borrowed payload slice.
    fn send_tracked(&mut self, h: AmHandle, wb: &mut WireBuilder<'_>, payload: &[u8]) -> bool {
        self.track(h, wb);
        let sent = self.send_wire(wb, payload);
        self.tracked_outcome(h, sent)
    }

    /// Tracked send of one chunk with a `fill`-produced payload (see
    /// [`Self::send_wire_with`]).
    fn send_tracked_with(
        &mut self,
        h: AmHandle,
        wb: &mut WireBuilder<'_>,
        payload_len: usize,
        fill: impl FnOnce(&mut [u8]) -> Result<()>,
    ) -> bool {
        self.track(h, wb);
        let sent = self.send_wire_with(wb, payload_len, fill);
        self.tracked_outcome(h, sent)
    }

    /// The fast-path registry, cloned out so a borrowed `LocalPeer` does not
    /// pin `self` (the operations need `&mut self` for the router/pool).
    /// Empty while `force_wire` is set: every send then takes the codec +
    /// router path even to a same-node kernel.
    fn local(&self) -> Option<Arc<LocalFastPath>> {
        if self.force_wire {
            return None;
        }
        self.fastpath.clone()
    }

    /// Resolve a locally-completed operation through the completion table so
    /// *both* completion models agree: the returned handle is already
    /// complete for `wait`/`test`, and each chunk bumps the cumulative
    /// counter the `wait_replies` shim reads (exactly what the wire acks
    /// would have done).
    fn complete_local(&self, flags: AmFlags, chunks: u64) -> AmHandle {
        if flags.is_async() {
            return AmHandle::completed();
        }
        let h = self.completion.create(chunks);
        for _ in 0..chunks {
            let t = self.completion.bind_token(h);
            self.completion.resolve(t);
        }
        h
    }

    /// A fast-path put whose destination write failed keeps the wire path's
    /// failure shape: the send call itself still succeeds (the ingress
    /// engine drops bad writes at the destination), asynchronous ops
    /// complete vacuously, and tracked ops fail their handle so the loss is
    /// attributed to the exact operation (surfacing as
    /// [`Error::OperationFailed`] at `wait`, and failing fast through the
    /// `wait_replies` shim) instead of hanging until timeout.
    fn local_put_failed(&self, flags: AmFlags, chunks: u64, e: &Error) -> AmHandle {
        log::warn!("kernel {}: local one-sided put dropped: {e}", self.id);
        if flags.is_async() {
            return AmHandle::completed();
        }
        let h = self.completion.create(chunks);
        self.completion.fail(h, &format!("local put failed: {e}"));
        h
    }

    /// Enqueue one payload-free notification AM of a fast-path put whose
    /// handler is user-registered: an asynchronous Short with the same
    /// handler id and args, routed normally so the handler runs on the
    /// destination's handler thread — strictly after the one-sided write is
    /// visible.
    fn notify_put(&mut self, dst: u16, handler: u8, args: &[u64]) -> Result<()> {
        let wb = WireBuilder {
            am_type: AmType::Short,
            flags: AmFlags::new().with(AmFlags::ASYNC),
            src: self.id,
            dst,
            handler,
            token: 0,
            args,
            desc: WireDesc::None,
        };
        self.send_wire(&wb, &[])
    }

    /// Complete a fast-path put whose data is already written: fire the
    /// payload-free notifications when the handler is user-registered — one
    /// **per chunk**, mirroring the wire path's per-chunk handler dispatch,
    /// so invocation counts do not depend on kernel placement — then resolve
    /// the handle. A notification that cannot be enqueued (router gone)
    /// fails the handle like any other lost send; the put call itself still
    /// succeeds.
    fn finish_local_put(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        flags: AmFlags,
        chunks: u64,
        notify: bool,
    ) -> AmHandle {
        if notify {
            for _ in 0..chunks {
                if let Err(e) = self.notify_put(dst, handler, args) {
                    log::warn!("kernel {}: fast-path notification lost: {e}", self.id);
                    if flags.is_async() {
                        return AmHandle::completed();
                    }
                    let h = self.completion.create(chunks);
                    self.completion.fail(h, &format!("handler notification failed: {e}"));
                    return h;
                }
            }
        }
        self.complete_local(flags, chunks)
    }

    // -- Short ---------------------------------------------------------------

    /// Send a Short AM (signaling; no payload). Returns after local emit.
    pub fn am_short(&mut self, dst: u16, handler: u8, args: &[u64]) -> Result<AmHandle> {
        self.am_short_flags(dst, handler, args, AmFlags::new())
    }

    /// Asynchronous Short AM — no reply will be generated; the returned
    /// handle is already complete.
    pub fn am_short_async(&mut self, dst: u16, handler: u8, args: &[u64]) -> Result<AmHandle> {
        self.am_short_flags(dst, handler, args, AmFlags::new().with(AmFlags::ASYNC))
    }

    fn am_short_flags(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        flags: AmFlags,
    ) -> Result<AmHandle> {
        if !self.profile().short {
            return Err(Error::ProfileViolation("short"));
        }
        self.spec.kernel(dst)?;
        let mut wb = WireBuilder {
            am_type: AmType::Short,
            flags,
            src: self.id,
            dst,
            handler,
            token: 0,
            args,
            desc: WireDesc::None,
        };
        if flags.is_async() {
            self.send_wire(&wb, &[])?;
            return Ok(AmHandle::completed());
        }
        let h = self.completion.create(1);
        self.send_tracked(h, &mut wb, &[]);
        Ok(h)
    }

    // -- Medium ---------------------------------------------------------------

    /// Medium FIFO put: payload from this kernel to the destination kernel's
    /// stream ("point-to-point communication for one kernel to send data
    /// directly to another kernel").
    pub fn am_medium(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
    ) -> Result<AmHandle> {
        self.medium_impl(dst, handler, args, payload, AmFlags::new().with(AmFlags::FIFO))
    }

    /// Asynchronous Medium FIFO put.
    pub fn am_medium_async(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
    ) -> Result<AmHandle> {
        self.medium_impl(
            dst,
            handler,
            args,
            payload,
            AmFlags::new().with(AmFlags::FIFO).with(AmFlags::ASYNC),
        )
    }

    /// Medium put whose payload the runtime reads from this kernel's memory
    /// partition (`src_offset`, `len`) — the non-FIFO variant of §III-A. The
    /// segment bytes are copied straight into the wire buffer (or straight
    /// onto a local destination's stream): no intermediate payload buffer.
    pub fn am_medium_from_mem(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        src_offset: u64,
        len: usize,
    ) -> Result<AmHandle> {
        let flags = AmFlags::new();
        if !self.profile().medium {
            return Err(Error::ProfileViolation("medium"));
        }
        self.spec.kernel(dst)?;
        let mut wb = self.medium_builder(dst, handler, args, flags, len)?;
        // Validate the read range up front (errors before any send).
        self.segment.check_range(src_offset, len)?;
        if let Some(fp) = self.local() {
            if let Some(peer) = fp.peer(self.node, dst) {
                if peer.medium_put_direct(handler) {
                    let data = self.segment.read(src_offset, len)?;
                    let h = self.completion.create(1);
                    let t = self.completion.bind_token(h);
                    match peer.deliver_medium_owned(self.id, handler, t, args, data) {
                        Ok(()) => self.completion.resolve(t),
                        Err(e) => self.completion.fail(h, &format!("local delivery failed: {e}")),
                    }
                    return Ok(h);
                }
            }
        }
        let seg = self.segment.clone();
        let h = self.completion.create(1);
        self.send_tracked_with(h, &mut wb, len, |out| seg.read_into(src_offset, out));
        Ok(h)
    }

    /// Shared Medium header shape + the no-chunking size gate.
    fn medium_builder<'a>(
        &self,
        dst: u16,
        handler: u8,
        args: &'a [u64],
        flags: AmFlags,
        payload_len: usize,
    ) -> Result<WireBuilder<'a>> {
        let wb = WireBuilder {
            am_type: AmType::Medium,
            flags,
            src: self.id,
            dst,
            handler,
            token: 0,
            args,
            desc: WireDesc::None,
        };
        if payload_len > wb.max_payload() {
            // Medium payloads are a kernel-stream datum; chunking would change
            // message boundaries, so it is always an error (the Jacobi halo
            // exchange failure mode of §IV-C1).
            return Err(Error::AmTooLarge { payload: payload_len, limit: wb.max_payload() });
        }
        Ok(wb)
    }

    fn medium_impl(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
        flags: AmFlags,
    ) -> Result<AmHandle> {
        if !self.profile().medium {
            return Err(Error::ProfileViolation("medium"));
        }
        self.spec.kernel(dst)?;
        let mut wb = self.medium_builder(dst, handler, args, flags, payload.len())?;
        // Intra-node fast path: the payload goes straight onto the local
        // destination's kernel stream (built-in handler ids only — a
        // registered user handler's contract includes the payload, so it
        // keeps the handler-thread path). Delivery before resolution, like
        // the ingress engine, so a woken waiter finds the data queued.
        if let Some(fp) = self.local() {
            if let Some(peer) = fp.peer(self.node, dst) {
                if peer.medium_put_direct(handler) {
                    if flags.is_async() {
                        peer.deliver_medium(self.id, handler, 0, args, payload)?;
                        return Ok(AmHandle::completed());
                    }
                    let h = self.completion.create(1);
                    let t = self.completion.bind_token(h);
                    match peer.deliver_medium(self.id, handler, t, args, payload) {
                        Ok(()) => self.completion.resolve(t),
                        Err(e) => self.completion.fail(h, &format!("local delivery failed: {e}")),
                    }
                    return Ok(h);
                }
            }
        }
        if flags.is_async() {
            self.send_wire(&wb, payload)?;
            return Ok(AmHandle::completed());
        }
        let h = self.completion.create(1);
        self.send_tracked(h, &mut wb, payload);
        Ok(h)
    }

    /// Medium get: bring `len` bytes at `src_addr` in the destination
    /// kernel's partition back to this kernel's stream. The data arrives as
    /// a [`ReceivedMedium`] per emitted chunk; the handle completes when
    /// every chunk's data reply has arrived.
    pub fn am_medium_get(
        &mut self,
        dst: u16,
        handler: u8,
        src_addr: u64,
        len: usize,
    ) -> Result<AmHandle> {
        if !self.profile().medium || !self.profile().gets {
            return Err(Error::ProfileViolation("medium get"));
        }
        self.spec.kernel(dst)?;
        let max = WireBuilder {
            am_type: AmType::Medium,
            flags: AmFlags::new().with(AmFlags::GET),
            src: self.id,
            dst,
            handler,
            token: 0,
            args: &[0],
            desc: WireDesc::MediumGet { src_addr, len: 0 },
        }
        .max_payload();
        let chunks = self.chunk_ranges(len, max)?;
        // Validate every chunk's address arithmetic *before* registering the
        // operation, so an overflow cannot abandon a half-issued handle.
        let descs = chunks
            .iter()
            .map(|&(off, clen)| {
                Ok((off, WireDesc::MediumGet {
                    src_addr: checked_offset(src_addr, off)?,
                    len: clen as u32,
                }))
            })
            .collect::<Result<Vec<_>>>()?;
        // Intra-node fast path: read the target segment and deliver onto our
        // own stream directly — the data reply without codec or router. Gets
        // never dispatch handlers (matching the ingress engine).
        if let Some(fp) = self.local() {
            if let (Some(peer), Some(me)) = (fp.peer(self.node, dst), fp.peer(self.node, self.id))
            {
                let h = self.completion.create(descs.len() as u64);
                for &(off, desc) in &descs {
                    let WireDesc::MediumGet { src_addr, len } = desc else { unreachable!() };
                    let t = self.completion.bind_token(h);
                    let served =
                        peer.serve_medium_get(me, dst, handler, t, src_addr, len as usize, off);
                    match served {
                        Ok(()) => self.completion.resolve(t),
                        Err(e) => {
                            self.completion.fail(h, &format!("local medium get failed: {e}"));
                            break;
                        }
                    }
                }
                return Ok(h);
            }
        }
        let h = self.completion.create(descs.len() as u64);
        for (off, desc) in descs {
            let chunk_args = [off];
            let mut wb = WireBuilder {
                am_type: AmType::Medium,
                flags: AmFlags::new().with(AmFlags::GET),
                src: self.id,
                dst,
                handler,
                token: 0,
                // Final arg carries the chunk's byte offset so the receiver
                // can reassemble multi-chunk gets.
                args: &chunk_args,
                desc,
            };
            if !self.send_tracked(h, &mut wb, &[]) {
                break;
            }
        }
        Ok(h)
    }

    // -- Long -----------------------------------------------------------------

    /// Long FIFO put: payload from this kernel, written into the destination
    /// kernel's partition at `dst_addr`.
    pub fn am_long(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
        dst_addr: u64,
    ) -> Result<AmHandle> {
        self.long_impl(dst, handler, args, payload, dst_addr, AmFlags::new().with(AmFlags::FIFO))
    }

    /// Asynchronous Long FIFO put.
    pub fn am_long_async(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
        dst_addr: u64,
    ) -> Result<AmHandle> {
        self.long_impl(
            dst,
            handler,
            args,
            payload,
            dst_addr,
            AmFlags::new().with(AmFlags::FIFO).with(AmFlags::ASYNC),
        )
    }

    /// Long put whose payload the runtime reads from this kernel's
    /// partition. Locally this is a direct segment-to-segment copy; remotely
    /// the segment bytes are copied straight into the wire buffer — no
    /// intermediate payload buffer either way.
    pub fn am_long_from_mem(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        src_offset: u64,
        len: usize,
        dst_addr: u64,
    ) -> Result<AmHandle> {
        self.long_from_mem_flags(dst, handler, args, src_offset, len, dst_addr, AmFlags::new())
    }

    /// [`am_long_from_mem`](Self::am_long_from_mem) with caller-chosen flags
    /// (the [`Rma`](crate::shoal_node::rma::Rma) tier's `Completion::Async`
    /// maps here with the ASYNC flag set).
    // 8 params: the full long-AM descriptor; every public variant narrows it.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn long_from_mem_flags(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        src_offset: u64,
        len: usize,
        dst_addr: u64,
        flags: AmFlags,
    ) -> Result<AmHandle> {
        let plan = self.long_plan(dst, handler, args, len, dst_addr, flags)?;
        self.segment.check_range(src_offset, len)?;
        if let Some(fp) = self.local() {
            if let Some(peer) = fp.peer(self.node, dst) {
                match peer.put_disposition(handler) {
                    PutDisposition::SlowPath => {}
                    disposition => {
                        if let Err(e) =
                            peer.segment.copy_from(dst_addr, &self.segment, src_offset, len)
                        {
                            return Ok(self.local_put_failed(flags, plan.len() as u64, &e));
                        }
                        let notify = disposition == PutDisposition::Notify;
                        let chunks = plan.len() as u64;
                        return Ok(self.finish_local_put(dst, handler, args, flags, chunks, notify));
                    }
                }
            }
        }
        let seg = self.segment.clone();
        let h = if flags.is_async() {
            AmHandle::completed()
        } else {
            self.completion.create(plan.len() as u64)
        };
        for (off, clen, desc) in plan {
            let mut wb = WireBuilder {
                am_type: AmType::Long,
                flags,
                src: self.id,
                dst,
                handler,
                token: 0,
                args,
                desc,
            };
            let chunk_base = src_offset + off; // bounds pre-checked above
            if flags.is_async() {
                self.send_wire_with(&wb, clen, |out| seg.read_into(chunk_base, out))?;
            } else if !self.send_tracked_with(h, &mut wb, clen, |out| seg.read_into(chunk_base, out))
            {
                break;
            }
        }
        Ok(h)
    }

    /// Chunk a Long put of `len` bytes: the packet-cap bound, the cluster
    /// chunk policy, and every chunk's destination-address arithmetic are
    /// validated *before* anything is registered or sent.
    fn long_plan(
        &self,
        dst: u16,
        handler: u8,
        args: &[u64],
        len: usize,
        dst_addr: u64,
        flags: AmFlags,
    ) -> Result<Vec<(u64, usize, WireDesc<'static>)>> {
        if !self.profile().long {
            return Err(Error::ProfileViolation("long"));
        }
        self.spec.kernel(dst)?;
        let max = WireBuilder {
            am_type: AmType::Long,
            flags,
            src: self.id,
            dst,
            handler,
            token: 0,
            args,
            desc: WireDesc::Long { dst_addr },
        }
        .max_payload();
        let chunks = self.chunk_ranges(len, max)?;
        chunks
            .iter()
            .map(|&(off, clen)| {
                Ok((off, clen, WireDesc::Long { dst_addr: checked_offset(dst_addr, off)? }))
            })
            .collect::<Result<Vec<_>>>()
    }

    fn long_impl(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
        dst_addr: u64,
        flags: AmFlags,
    ) -> Result<AmHandle> {
        let plan = self.long_plan(dst, handler, args, payload.len(), dst_addr, flags)?;
        // Intra-node one-sided fast path: write the destination partition
        // directly from the caller's slice — zero copies, no codec, no
        // router — and resolve the handle immediately. A registered user
        // handler still fires via the payload-free notification AM, strictly
        // after the data is visible.
        if let Some(fp) = self.local() {
            if let Some(peer) = fp.peer(self.node, dst) {
                match peer.put_disposition(handler) {
                    PutDisposition::SlowPath => {}
                    disposition => {
                        if let Err(e) = peer.segment.write(dst_addr, payload) {
                            return Ok(self.local_put_failed(flags, plan.len() as u64, &e));
                        }
                        let notify = disposition == PutDisposition::Notify;
                        let chunks = plan.len() as u64;
                        return Ok(self.finish_local_put(dst, handler, args, flags, chunks, notify));
                    }
                }
            }
        }
        let h = if flags.is_async() {
            AmHandle::completed()
        } else {
            self.completion.create(plan.len() as u64)
        };
        for (off, clen, desc) in plan {
            let mut wb = WireBuilder {
                am_type: AmType::Long,
                flags,
                src: self.id,
                dst,
                handler,
                token: 0,
                args,
                desc,
            };
            // Chunking slices the caller's payload — no per-chunk buffer.
            let chunk = &payload[off as usize..off as usize + clen];
            if flags.is_async() {
                self.send_wire(&wb, chunk)?;
            } else if !self.send_tracked(h, &mut wb, chunk) {
                break;
            }
        }
        Ok(h)
    }

    /// Long get: read `len` bytes at `src_addr` in the destination kernel's
    /// partition; the reply writes them at `reply_addr` in *this* kernel's
    /// partition. Completion = `wait(handle)` (or the `wait_replies` shim).
    pub fn am_long_get(
        &mut self,
        dst: u16,
        handler: u8,
        src_addr: u64,
        len: usize,
        reply_addr: u64,
    ) -> Result<AmHandle> {
        if !self.profile().long || !self.profile().gets {
            return Err(Error::ProfileViolation("long get"));
        }
        self.spec.kernel(dst)?;
        // The chunk bound comes from the *reply* (a Long data reply carries
        // the payload back).
        let max = WireBuilder {
            am_type: AmType::Long,
            flags: AmFlags::new().with(AmFlags::REPLY),
            src: dst,
            dst: self.id,
            handler,
            token: 0,
            args: &[],
            desc: WireDesc::Long { dst_addr: reply_addr },
        }
        .max_payload();
        let chunks = self.chunk_ranges(len, max)?;
        // Address arithmetic validated before the operation is registered.
        let descs = chunks
            .iter()
            .map(|&(off, clen)| {
                Ok(WireDesc::LongGet {
                    src_addr: checked_offset(src_addr, off)?,
                    len: clen as u32,
                    reply_addr: checked_offset(reply_addr, off)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        // Intra-node fast path: one-sided read — a direct segment-to-segment
        // copy from the target partition into ours (gets never dispatch
        // handlers, matching the ingress engine).
        if let Some(fp) = self.local() {
            if let Some(peer) = fp.peer(self.node, dst) {
                let h = self.completion.create(descs.len() as u64);
                for &desc in &descs {
                    let WireDesc::LongGet { src_addr, len, reply_addr } = desc else {
                        unreachable!()
                    };
                    let t = self.completion.bind_token(h);
                    let copied =
                        self.segment.copy_from(reply_addr, &peer.segment, src_addr, len as usize);
                    match copied {
                        Ok(()) => self.completion.resolve(t),
                        Err(e) => {
                            self.completion.fail(h, &format!("local long get failed: {e}"));
                            break;
                        }
                    }
                }
                return Ok(h);
            }
        }
        let h = self.completion.create(descs.len() as u64);
        for desc in descs {
            let mut wb = WireBuilder {
                am_type: AmType::Long,
                flags: AmFlags::new().with(AmFlags::GET),
                src: self.id,
                dst,
                handler,
                token: 0,
                args: &[],
                desc,
            };
            if !self.send_tracked(h, &mut wb, &[]) {
                break;
            }
        }
        Ok(h)
    }

    /// Strided Long put: block `i` of `block_len` bytes lands at
    /// `dst_addr + i*stride` in the destination's partition.
    pub fn am_long_strided(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
        dst_addr: u64,
        stride: u32,
        block_len: u32,
    ) -> Result<AmHandle> {
        if !self.profile().strided {
            return Err(Error::ProfileViolation("strided"));
        }
        self.spec.kernel(dst)?;
        if block_len == 0 || payload.len() % block_len as usize != 0 {
            return Err(Error::BadDescriptor(format!(
                "strided payload {} not a multiple of block_len {block_len}",
                payload.len()
            )));
        }
        let nblocks = (payload.len() / block_len as usize) as u32;
        let flags = AmFlags::new().with(AmFlags::FIFO);
        let mut wb = WireBuilder {
            am_type: AmType::LongStrided,
            flags,
            src: self.id,
            dst,
            handler,
            token: 0,
            args,
            desc: WireDesc::Strided { dst_addr, stride, block_len, nblocks },
        };
        wb.validate(payload.len())?;
        if payload.len() > wb.max_payload() {
            return Err(Error::AmTooLarge { payload: payload.len(), limit: wb.max_payload() });
        }
        // Intra-node fast path: scatter straight into the local partition.
        if let Some(fp) = self.local() {
            if let Some(peer) = fp.peer(self.node, dst) {
                match peer.put_disposition(handler) {
                    PutDisposition::SlowPath => {}
                    disposition => {
                        if let Err(e) =
                            peer.segment.write_strided(dst_addr, stride, block_len, payload)
                        {
                            return Ok(self.local_put_failed(flags, 1, &e));
                        }
                        let notify = disposition == PutDisposition::Notify;
                        return Ok(self.finish_local_put(dst, handler, args, flags, 1, notify));
                    }
                }
            }
        }
        let h = self.completion.create(1);
        self.send_tracked(h, &mut wb, payload);
        Ok(h)
    }

    /// Vectored Long put: payload split over explicit (addr, len) extents.
    pub fn am_long_vectored(
        &mut self,
        dst: u16,
        handler: u8,
        args: &[u64],
        payload: &[u8],
        entries: &[(u64, u32)],
    ) -> Result<AmHandle> {
        if !self.profile().vectored {
            return Err(Error::ProfileViolation("vectored"));
        }
        self.spec.kernel(dst)?;
        let flags = AmFlags::new().with(AmFlags::FIFO);
        let mut wb = WireBuilder {
            am_type: AmType::LongVectored,
            flags,
            src: self.id,
            dst,
            handler,
            token: 0,
            args,
            desc: WireDesc::Vectored { entries },
        };
        wb.validate(payload.len())?;
        if payload.len() > wb.max_payload() {
            return Err(Error::AmTooLarge { payload: payload.len(), limit: wb.max_payload() });
        }
        // Intra-node fast path: scatter the extents straight into the local
        // partition.
        if let Some(fp) = self.local() {
            if let Some(peer) = fp.peer(self.node, dst) {
                match peer.put_disposition(handler) {
                    PutDisposition::SlowPath => {}
                    disposition => {
                        if let Err(e) = peer.segment.write_vectored(entries, payload) {
                            return Ok(self.local_put_failed(flags, 1, &e));
                        }
                        let notify = disposition == PutDisposition::Notify;
                        return Ok(self.finish_local_put(dst, handler, args, flags, 1, notify));
                    }
                }
            }
        }
        let h = self.completion.create(1);
        self.send_tracked(h, &mut wb, payload);
        Ok(h)
    }

    // -- atomics ---------------------------------------------------------------

    /// Scalar remote atomic on the 8-byte little-endian word at `addr` in
    /// the destination kernel's partition: FAA (add/min/max/and/or/xor),
    /// compare-and-swap (`operand` = expected, `operand2` = replacement) or
    /// swap. The op executes **at the target's AM engine** — serialized with
    /// every other atomic on that word regardless of datapath — and the
    /// pre-op value returns on the reply path into the handle; extract it
    /// with [`wait_fetch`](Self::wait_fetch). On a same-node software
    /// destination the op executes lock-free against the target segment and
    /// the handle resolves (value included) at issue time.
    pub fn am_atomic(
        &mut self,
        dst: u16,
        addr: u64,
        op: AtomicOp,
        operand: u64,
        operand2: u64,
    ) -> Result<AmHandle> {
        self.atomic_impl(dst, addr, op, operand, operand2, AmFlags::new())
    }

    /// Asynchronous scalar atomic: the update is applied at the target but
    /// no reply is generated — the pre-op value is discarded and the
    /// returned handle is already complete. A lost message is silently lost
    /// (as for every async AM).
    pub fn am_atomic_async(
        &mut self,
        dst: u16,
        addr: u64,
        op: AtomicOp,
        operand: u64,
        operand2: u64,
    ) -> Result<AmHandle> {
        self.atomic_impl(dst, addr, op, operand, operand2, AmFlags::new().with(AmFlags::ASYNC))
    }

    fn atomic_impl(
        &mut self,
        dst: u16,
        addr: u64,
        op: AtomicOp,
        operand: u64,
        operand2: u64,
        flags: AmFlags,
    ) -> Result<AmHandle> {
        if op.is_accumulate() {
            return Err(Error::BadDescriptor(format!(
                "{op} carries an element-wise payload; use am_accumulate"
            )));
        }
        self.spec.kernel(dst)?;
        let mut wb = WireBuilder {
            am_type: AmType::Atomic,
            flags,
            src: self.id,
            dst,
            handler: handler_ids::REPLY,
            token: 0,
            args: &[],
            desc: WireDesc::Atomic { addr, op, lane: Lane::U64, operand, operand2 },
        };
        // Intra-node fast path: execute against the target segment via the
        // same executor the ingress engine uses (lock-free for aligned
        // words) and resolve the handle — fetched value included — at issue
        // time. Atomics never dispatch handlers, so every local software
        // peer is eligible, like gets.
        if let Some(fp) = self.local() {
            if let Some(peer) = fp.peer(self.node, dst) {
                return Ok(self.finish_local_atomic(
                    execute_atomic(&peer.segment, addr, op, Lane::U64, operand, operand2, &[]),
                    flags,
                ));
            }
        }
        if flags.is_async() {
            self.send_wire(&wb, &[])?;
            return Ok(AmHandle::completed());
        }
        let h = self.completion.create(1);
        self.send_tracked(h, &mut wb, &[]);
        Ok(h)
    }

    /// Element-wise remote accumulate: fold `payload` (8-byte lanes of
    /// `lane`, reduction `op`) into the destination partition starting at
    /// `addr`. Accumulates fetch nothing: the handle completes on the
    /// ordinary ack (or at issue time on the fast path) and is consumed with
    /// the plain [`wait`](Self::wait) family.
    pub fn am_accumulate(
        &mut self,
        dst: u16,
        addr: u64,
        op: ReduceOp,
        lane: Lane,
        payload: &[u8],
    ) -> Result<AmHandle> {
        self.accumulate_impl(dst, addr, op, lane, payload, AmFlags::new())
    }

    /// Asynchronous accumulate — applied at the target, no ack.
    pub fn am_accumulate_async(
        &mut self,
        dst: u16,
        addr: u64,
        op: ReduceOp,
        lane: Lane,
        payload: &[u8],
    ) -> Result<AmHandle> {
        self.accumulate_impl(dst, addr, op, lane, payload, AmFlags::new().with(AmFlags::ASYNC))
    }

    fn accumulate_impl(
        &mut self,
        dst: u16,
        addr: u64,
        op: ReduceOp,
        lane: Lane,
        payload: &[u8],
        flags: AmFlags,
    ) -> Result<AmHandle> {
        self.spec.kernel(dst)?;
        let aop = AtomicOp::accumulate(op);
        if payload.is_empty() || payload.len() % 8 != 0 {
            return Err(Error::BadDescriptor(format!(
                "accumulate payload of {} bytes is not a whole number of 8-byte lanes",
                payload.len()
            )));
        }
        let mut wb = WireBuilder {
            am_type: AmType::Atomic,
            flags,
            src: self.id,
            dst,
            handler: handler_ids::REPLY,
            token: 0,
            args: &[],
            desc: WireDesc::Atomic { addr, op: aop, lane, operand: 0, operand2: 0 },
        };
        // Accumulates ride one AM: splitting would be semantically fine
        // (the fold is element-wise) but would blur the one-op-one-handle
        // failure attribution, so oversized payloads are an error.
        if payload.len() > wb.max_payload() {
            return Err(Error::AmTooLarge { payload: payload.len(), limit: wb.max_payload() });
        }
        if let Some(fp) = self.local() {
            if let Some(peer) = fp.peer(self.node, dst) {
                return Ok(self.finish_local_atomic(
                    execute_atomic(&peer.segment, addr, aop, lane, 0, 0, payload),
                    flags,
                ));
            }
        }
        if flags.is_async() {
            self.send_wire(&wb, payload)?;
            return Ok(AmHandle::completed());
        }
        let h = self.completion.create(1);
        self.send_tracked(h, &mut wb, payload);
        Ok(h)
    }

    /// Turn a fast-path atomic's outcome into a handle with the wire path's
    /// shape: success resolves the handle carrying the pre-op value, failure
    /// fails it (surfacing at `wait`/`wait_fetch` as
    /// [`Error::OperationFailed`]), and asynchronous ops complete vacuously
    /// either way.
    fn finish_local_atomic(&self, outcome: Result<u64>, flags: AmFlags) -> AmHandle {
        match outcome {
            Ok(old) => {
                if flags.is_async() {
                    return AmHandle::completed();
                }
                let h = self.completion.create(1);
                let t = self.completion.bind_token(h);
                self.completion.resolve_with(t, old);
                h
            }
            Err(e) => {
                log::warn!("kernel {}: local atomic dropped: {e}", self.id);
                if flags.is_async() {
                    return AmHandle::completed();
                }
                let h = self.completion.create(1);
                self.completion.fail(h, &format!("local atomic failed: {e}"));
                h
            }
        }
    }

    // -- completion ------------------------------------------------------------

    /// Block until `h` completes, consuming it. A failed send surfaces its
    /// reason as [`Error::OperationFailed`]; a timeout leaves the operation
    /// outstanding. Waiting an already-consumed handle succeeds without
    /// double-crediting the `wait_replies` bookkeeping.
    pub fn wait(&mut self, h: AmHandle) -> Result<()> {
        if self.completion.wait(h, self.timeout)? {
            self.consumed += h.messages;
        }
        Ok(())
    }

    /// Nonblocking completion probe: `Ok(true)` consumes the handle,
    /// `Ok(false)` means still in flight, `Err` surfaces a failed send.
    pub fn test(&mut self, h: AmHandle) -> Result<bool> {
        match self.completion.test(h)? {
            None => Ok(false),
            Some(first) => {
                if first {
                    self.consumed += h.messages;
                }
                Ok(true)
            }
        }
    }

    /// Block until every handle in `hs` completes (consuming all of them) —
    /// the fence after a batch of overlapped transfers. Handles already
    /// consumed (e.g. by an earlier `wait_any`) are skipped harmlessly. An
    /// empty slice is a vacuous fence: it returns `Ok(())` immediately
    /// (every one of zero handles is complete), unlike
    /// [`wait_any`](ShoalKernel::wait_any) for which emptiness is an error.
    pub fn wait_all(&mut self, hs: &[AmHandle]) -> Result<()> {
        let deadline = std::time::Instant::now() + self.timeout;
        for h in hs {
            let now = std::time::Instant::now();
            let left = if now >= deadline { Duration::ZERO } else { deadline - now };
            if self.completion.wait(*h, left)? {
                self.consumed += h.messages;
            }
        }
        Ok(())
    }

    /// Block until the fetch atomic `h` completes, consuming it, and return
    /// the pre-op value of the target word. Fails with
    /// [`Error::OperationFailed`] if the operation failed, or if `h` is not
    /// an unconsumed fetch (accumulates and plain sends carry no value; a
    /// value is extracted exactly once).
    pub fn wait_fetch(&mut self, h: AmHandle) -> Result<u64> {
        let (v, first) = self.completion.wait_value(h, self.timeout)?;
        if first {
            self.consumed += h.messages;
        }
        Ok(v)
    }

    /// Block until *any* handle in `hs` completes; returns the index of the
    /// completed handle (consuming only that one). An empty slice returns
    /// [`Error::EmptyWaitSet`] immediately — nothing could ever complete.
    pub fn wait_any(&mut self, hs: &[AmHandle]) -> Result<usize> {
        let (i, first) = self.completion.wait_any(hs, self.timeout)?;
        if first {
            self.consumed += hs[i].messages;
        }
        Ok(i)
    }

    /// Block until `n` more replies have arrived — the paper's collective
    /// completion model, retained as a shim over the completion table
    /// (callers sum the `AmHandle::messages` of the operations they wait
    /// on). Do not mix with handle waits *for the same operations*.
    ///
    /// Deprecated: counter-style completion cannot attribute a failure or a
    /// fetched value to an operation — wait on the per-operation handles
    /// ([`wait`](Self::wait), [`wait_all`](Self::wait_all),
    /// [`wait_fetch`](Self::wait_fetch)) instead. The shim keeps compiling
    /// and behaves as before; it only warns.
    #[deprecated(
        note = "wait on per-operation handles (wait/wait_all/wait_fetch) instead; \
                counter-style completion cannot attribute failures or fetch results"
    )]
    pub fn wait_replies(&mut self, n: u64) -> Result<()> {
        let target = self.consumed + n;
        self.completion.wait_total(target, self.timeout)?;
        self.consumed = target;
        Ok(())
    }

    /// Replies received but not yet consumed by any wait. Saturates at zero:
    /// double-consuming a handle (wait/test after it already settled) can
    /// push the consumed count past the resolved count.
    pub fn pending_replies(&self) -> u64 {
        self.completion.resolved_total().saturating_sub(self.consumed)
    }

    /// Blocking receive of the next Medium payload.
    pub fn recv_medium(&self) -> Result<ReceivedMedium> {
        self.medium_rx
            .recv_timeout(self.timeout)
            .map_err(|e| match e {
                std::sync::mpsc::RecvTimeoutError::Timeout => Error::Timeout("medium receive"),
                _ => Error::Disconnected("medium stream"),
            })
    }

    /// Non-blocking receive.
    pub fn try_recv_medium(&self) -> Result<Option<ReceivedMedium>> {
        match self.medium_rx.try_recv() {
            Ok(m) => Ok(Some(m)),
            Err(std::sync::mpsc::TryRecvError::Empty) => Ok(None),
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                Err(Error::Disconnected("medium stream"))
            }
        }
    }

    /// Register a user handler (software kernels only, as in the paper).
    pub fn register_handler(
        &self,
        id: u8,
        f: impl Fn(crate::am::handlers::HandlerArgs<'_>) + Send + Sync + 'static,
    ) -> Result<()> {
        if !self.profile().user_handlers {
            return Err(Error::ProfileViolation("user handlers"));
        }
        self.handlers.register(id, Box::new(f))
    }

    // -- barrier ----------------------------------------------------------------

    /// Cluster-wide barrier over Short AMs. The lowest kernel id acts as the
    /// master: it counts ENTER messages and broadcasts RELEASE.
    pub fn barrier(&mut self) -> Result<()> {
        if !self.profile().barrier {
            return Err(Error::ProfileViolation("barrier"));
        }
        self.epoch += 1;
        let epoch = self.epoch;
        let mut ids: Vec<u16> = self.spec.kernels.iter().map(|k| k.id).collect();
        ids.sort_unstable();
        let master = ids[0];
        let n = ids.len() as u64;
        if n == 1 {
            return Ok(());
        }
        if self.id == master {
            // Seed membership so a timeout names never-arrived kernels too.
            self.barrier_state.note_members(&ids[1..]);
            self.barrier_state
                .wait_enters(epoch, n - 1, self.timeout)?;
            for &kid in ids.iter().skip(1) {
                // Fire-and-forget: release delivery is confirmed by the
                // peer leaving its `wait_release`, not by this handle.
                let _ = self.am_short_async(
                    kid,
                    handler_ids::BARRIER,
                    &[barrier_op::RELEASE, epoch],
                )?;
            }
            Ok(())
        } else {
            // Fire-and-forget: the master's RELEASE is the acknowledgment.
            let _ =
                self.am_short_async(master, handler_ids::BARRIER, &[barrier_op::ENTER, epoch])?;
            self.barrier_state.wait_release(epoch, self.timeout)
        }
    }

    // -- collectives ------------------------------------------------------------

    /// Lowest kernel id in the cluster — the implicit root of rootless
    /// collectives (`all_reduce`, `barrier_tree`), mirroring the counter
    /// barrier's master-selection rule.
    fn lowest_kernel(&self) -> u16 {
        self.spec.kernels.iter().map(|k| k.id).min().unwrap_or(self.id)
    }

    fn collective_impl(
        &mut self,
        kind: CollectiveKind,
        op: ReduceOp,
        lane: Lane,
        root: u16,
        data: &[u8],
    ) -> Result<CollectiveHandle> {
        // Collectives ride Medium AMs and generalize the barrier; both
        // capabilities must be in the active profile.
        if !self.profile().medium || !self.profile().barrier {
            return Err(Error::ProfileViolation("collectives"));
        }
        self.spec.kernel(root)?;
        if kind != CollectiveKind::Bcast && data.len() % 8 != 0 {
            return Err(Error::BadDescriptor(format!(
                "reduction payload of {} bytes is not a whole number of 8-byte lanes",
                data.len()
            )));
        }
        // Every tree hop carries the payload in one Medium AM; no chunking.
        let max = WireBuilder {
            am_type: AmType::Medium,
            flags: AmFlags::new().with(AmFlags::ASYNC),
            src: self.id,
            dst: self.id,
            handler: handler_ids::COLLECTIVE,
            token: 0,
            args: &[0, 0, 0],
            desc: WireDesc::None,
        }
        .max_payload();
        if data.len() > max {
            return Err(Error::AmTooLarge { payload: data.len(), limit: max });
        }
        self.coll_seq += 1;
        let seq = self.coll_seq;
        let desc = CollDesc { kind, op, lane, tree: TreeKind::Binomial, root };
        let h = self.completion.create(1);
        let token = self.completion.bind_token(h);
        let ingress = self.collective.begin(seq, desc, data, token)?;
        let mut send_failed = false;
        for m in &ingress.out {
            if let Err(e) = self.send_msg(m) {
                log::warn!("kernel {}: collective send failed: {e}", self.id);
                self.completion.fail(h, &format!("collective send failed: {e}"));
                send_failed = true;
                break;
            }
        }
        // Resolution strictly after the fan went out: a send failure leaves
        // the handle in flight so `fail` above transitions it to failed and
        // the caller's wait surfaces the error instead of a phantom success.
        if !send_failed {
            if let Some(t) = ingress.resolve {
                self.completion.resolve(t);
            }
        }
        Ok(CollectiveHandle { am: h, seq, kind })
    }

    /// Broadcast `data` from `root` down the collective tree. Non-root
    /// callers' `data` is ignored; every kernel's
    /// [`collective_wait`](ShoalKernel::collective_wait) returns the root's
    /// bytes. Nonblocking: the returned handle's `am` composes with
    /// `wait`/`test`/`wait_all`/`wait_any`.
    pub fn bcast(&mut self, root: u16, data: &[u8]) -> Result<CollectiveHandle> {
        self.collective_impl(CollectiveKind::Bcast, ReduceOp::Sum, Lane::U64, root, data)
    }

    /// Element-wise reduction of every kernel's `contribution` up the tree;
    /// the fold materializes at `root` (everyone else's result is empty).
    pub fn reduce(
        &mut self,
        root: u16,
        op: ReduceOp,
        lane: Lane,
        contribution: &[u8],
    ) -> Result<CollectiveHandle> {
        self.collective_impl(CollectiveKind::Reduce, op, lane, root, contribution)
    }

    /// Reduce-then-broadcast: every kernel contributes and every kernel's
    /// `collective_wait` returns the full fold — the primitive that lets
    /// workloads agree on global state (e.g. a convergence residual) in
    /// `O(log n)` hops instead of `n` point-to-point round trips.
    pub fn all_reduce(
        &mut self,
        op: ReduceOp,
        lane: Lane,
        contribution: &[u8],
    ) -> Result<CollectiveHandle> {
        let root = self.lowest_kernel();
        self.collective_impl(CollectiveKind::AllReduce, op, lane, root, contribution)
    }

    /// `all_reduce` over `u64` lanes.
    pub fn all_reduce_u64(&mut self, op: ReduceOp, vals: &[u64]) -> Result<CollectiveHandle> {
        self.all_reduce(op, Lane::U64, &encode_u64s(vals))
    }

    /// `all_reduce` over `f64` lanes.
    pub fn all_reduce_f64(&mut self, op: ReduceOp, vals: &[f64]) -> Result<CollectiveHandle> {
        self.all_reduce(op, Lane::F64, &encode_f64s(vals))
    }

    /// Block until the collective completes and return its result bytes
    /// (root's payload for `bcast`, the fold for `all_reduce` everywhere and
    /// `reduce` at the root, empty otherwise). A timeout is converted into
    /// [`Error::OperationFailed`] naming the straggler kernels — the
    /// collective analogue of the barrier's straggler diagnostic — and fails
    /// the handle so later waits agree. Safe to call after the handle was
    /// already consumed by `wait`/`wait_all` (it just fetches the result).
    pub fn collective_wait(&mut self, ch: CollectiveHandle) -> Result<Vec<u8>> {
        match self.wait(ch.am) {
            Ok(()) => self.collective.take_result(ch.seq),
            Err(Error::Timeout(_)) => {
                let (awaiting, down_from) = self.collective.pending(ch.seq);
                let reason = if !awaiting.is_empty() {
                    // Per-collective straggler naming: the coordinator
                    // ledger says how far each missing subtree ever got,
                    // separating a dead kernel from a merely lagging one.
                    let lag: Vec<String> = awaiting
                        .iter()
                        .map(|&kid| match self.collective.last_contribution(kid) {
                            Some(s) if s > 0 => format!("kernel {kid} (last at #{s})"),
                            _ => format!("kernel {kid} (never contributed)"),
                        })
                        .collect();
                    format!(
                        "collective #{} ({}) timed out: missing contributions from {}",
                        ch.seq,
                        ch.kind.label(),
                        lag.join(", ")
                    )
                } else if let Some(p) = down_from {
                    format!(
                        "collective #{} ({}) timed out: no result from parent kernel {p}",
                        ch.seq,
                        ch.kind.label()
                    )
                } else {
                    format!(
                        "collective #{} ({}) timed out before this kernel's part completed \
                         (cluster view: kernels {:?} had not reached it)",
                        ch.seq,
                        ch.kind.label(),
                        self.collective.ledger_stragglers(ch.seq)
                    )
                };
                self.completion.fail(ch.am, &reason);
                Err(Error::OperationFailed(reason))
            }
            Err(e) => Err(e),
        }
    }

    /// `collective_wait` decoding the result as `u64` lanes.
    pub fn collective_wait_u64(&mut self, ch: CollectiveHandle) -> Result<Vec<u64>> {
        decode_u64s(&self.collective_wait(ch)?)
    }

    /// `collective_wait` decoding the result as `f64` lanes.
    pub fn collective_wait_f64(&mut self, ch: CollectiveHandle) -> Result<Vec<f64>> {
        decode_f64s(&self.collective_wait(ch)?)
    }

    /// Nonblocking collective probe: `Ok(Some(result))` the first time the
    /// collective is observed complete (consuming it), `Ok(None)` while in
    /// flight.
    pub fn collective_test(&mut self, ch: CollectiveHandle) -> Result<Option<Vec<u8>>> {
        if self.test(ch.am)? {
            Ok(Some(self.collective.take_result(ch.seq)?))
        } else {
            Ok(None)
        }
    }

    /// Cluster-wide barrier over the collective tree: an all-reduce with an
    /// empty payload. `O(log n)` critical-path hops versus the counter
    /// barrier's `O(n)` fan at the master — the `barrier()` alternative for
    /// larger clusters.
    pub fn barrier_tree(&mut self) -> Result<()> {
        if !self.profile().barrier {
            return Err(Error::ProfileViolation("barrier"));
        }
        let root = self.lowest_kernel();
        let ch =
            self.collective_impl(CollectiveKind::Barrier, ReduceOp::Sum, Lane::U64, root, &[])?;
        self.collective_wait(ch).map(|_| ())
    }

    // -- helpers ----------------------------------------------------------------

    /// Split `len` bytes into per-message ranges obeying the packet cap and
    /// the cluster chunk policy. Returns (offset, len) pairs.
    fn chunk_ranges(&self, len: usize, max: usize) -> Result<Vec<(u64, usize)>> {
        if len <= max {
            return Ok(vec![(0, len)]);
        }
        match self.chunk_override.unwrap_or(self.spec.chunk_policy) {
            ChunkPolicy::Reject => Err(Error::AmTooLarge { payload: len, limit: max }),
            ChunkPolicy::Chunked => {
                let mut out = Vec::with_capacity(len.div_ceil(max));
                let mut off = 0usize;
                while off < len {
                    let clen = max.min(len - off);
                    out.push((off as u64, clen));
                    off += clen;
                }
                Ok(out)
            }
        }
    }
}

/// Chunk address arithmetic with overflow detection — a silent `u64` wrap
/// here would scatter a chunk to the bottom of the destination partition.
fn checked_offset(base: u64, off: u64) -> Result<u64> {
    base.checked_add(off).ok_or_else(|| {
        Error::BadDescriptor(format!("address overflow: {base:#x} + {off:#x} exceeds u64"))
    })
}
