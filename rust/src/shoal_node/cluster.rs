//! `ShoalCluster` — launch a whole heterogeneous cluster in one process.
//!
//! Mirrors the Galapagos deployment flow: from a `ClusterSpec` it brings up
//! every node (router + transport), attaches the Shoal runtime (handler
//! threads on software nodes, a GAScore per hardware node), and then runs
//! user *kernel functions* on threads — "Software Shoal kernels are defined
//! through a function pointer to a kernel function that gets started as a
//! separate thread by the software Shoal node" (§III-B). Hardware kernels
//! run the same way here, standing in for the HLS control logic, but their
//! runtime path goes through the GAScore simulator.

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::am::completion::CompletionTable;
use crate::am::engine::{BarrierState, KernelRuntime};
use crate::am::handlers::HandlerTable;
use crate::collectives::CollectiveState;
use crate::config::{ClusterSpec, Platform};
use crate::error::{Error, Result};
use crate::galapagos::node::{BoundNode, GalapagosNode};
use crate::galapagos::router::RouterHandle;
use crate::galapagos::transport::local::LocalFabric;
use crate::gascore::server::GAScoreServer;
use crate::gascore::GAScoreStats;
use crate::memory::Segment;
use crate::shoal_node::api::ShoalKernel;
use crate::shoal_node::fastpath::{LocalFastPath, LocalPeer};
use crate::shoal_node::handler_thread::HandlerThread;

/// A running cluster.
pub struct ShoalCluster {
    spec: Arc<ClusterSpec>,
    nodes: Vec<GalapagosNode>,
    handler_threads: Vec<HandlerThread>,
    gascores: Vec<GAScoreServer>,
    /// Egress adapter threads for hardware nodes.
    adapters: Vec<JoinHandle<()>>,
    /// Unclaimed kernel API handles.
    kernels: Mutex<HashMap<u16, ShoalKernel>>,
    /// Handler tables, kept for pre-run registration.
    tables: HashMap<u16, Arc<HandlerTable>>,
    /// Threads started by `run_kernel`.
    running: Mutex<Vec<(u16, JoinHandle<()>)>>,
}

impl ShoalCluster {
    /// Bring up every node of `spec` in this process and prepare one
    /// `ShoalKernel` handle per kernel.
    pub fn launch(spec: &ClusterSpec) -> Result<ShoalCluster> {
        Self::launch_inner(spec, None)
    }

    /// Bring up **one** node of a multi-process cluster: this process hosts
    /// `node_id`'s router/transport/runtime and kernels; every other node is
    /// reached at the address written in the spec (so the cluster file must
    /// use explicit ports, not `:0`). The Galapagos deployment model — one
    /// process per processor, one (simulated) bitstream per FPGA — run for
    /// real: see `shoal serve` and `rust/tests/multiprocess.rs`.
    pub fn launch_node(spec: &ClusterSpec, node_id: u16) -> Result<ShoalCluster> {
        spec.node(node_id)?;
        if spec.transport == crate::config::TransportKind::Local {
            return Err(Error::Config(
                "multi-process clusters need a network transport (tcp/udp)".into(),
            ));
        }
        Self::launch_inner(spec, Some(node_id))
    }

    fn launch_inner(spec: &ClusterSpec, only_node: Option<u16>) -> Result<ShoalCluster> {
        spec.validate()?;
        let spec = Arc::new(spec.clone());
        let fabric = LocalFabric::new();

        // Nodes this process hosts.
        let hosted: Vec<u16> = match only_node {
            Some(id) => vec![id],
            None => spec.nodes.iter().map(|n| n.id).collect(),
        };

        // Phase 1: bind hosted nodes, learn advertised addresses. Remote
        // nodes (multi-process mode) are reached at their spec addresses.
        let mut bound = Vec::new();
        for &node_id in &hosted {
            bound.push(BoundNode::bind(&spec, node_id)?);
        }
        let mut addrs: HashMap<u16, String> = spec
            .nodes
            .iter()
            .filter(|n| !hosted.contains(&n.id))
            .filter_map(|n| n.address.clone().map(|a| (n.id, a)))
            .collect();
        for b in &bound {
            if let Some(a) = b.advertised_addr.clone() {
                addrs.insert(b.node_id(), a);
            }
        }

        // Per-kernel runtime state.
        struct KState {
            segment: Segment,
            completion: Arc<CompletionTable>,
            barrier: Arc<BarrierState>,
            handlers: Arc<HandlerTable>,
            collective: Arc<CollectiveState>,
            medium_tx: mpsc::Sender<crate::am::engine::ReceivedMedium>,
            medium_rx: Option<mpsc::Receiver<crate::am::engine::ReceivedMedium>>,
        }
        // Collectives span the whole cluster (hosted or not): the tree is
        // computed over every kernel id.
        let all_kernel_ids: Vec<u16> = spec.kernels.iter().map(|k| k.id).collect();
        let mut kstate: HashMap<u16, KState> = HashMap::new();
        let mut tables = HashMap::new();
        for k in spec.kernels.iter().filter(|k| hosted.contains(&k.node)) {
            let platform = spec.node(k.node)?.platform;
            let handlers = Arc::new(match platform {
                Platform::Sw => HandlerTable::software(),
                Platform::Hw => HandlerTable::hardware(),
            });
            tables.insert(k.id, Arc::clone(&handlers));
            let (mtx, mrx) = mpsc::channel();
            let completion = CompletionTable::new();
            kstate.insert(
                k.id,
                KState {
                    segment: Segment::new(k.segment_size),
                    collective: CollectiveState::new(
                        k.id,
                        all_kernel_ids.clone(),
                        Arc::clone(&completion),
                    ),
                    completion,
                    barrier: BarrierState::new(),
                    handlers,
                    medium_tx: mtx,
                    medium_rx: Some(mrx),
                },
            );
        }

        // Intra-node one-sided fast path registry: one peer entry per hosted
        // *software* kernel (hardware kernels keep the GAScore ingress for
        // cycle accounting, and the registry's same-node check keeps
        // transports honest). `local_fastpath = false` turns it off — every
        // AM then takes the full codec + router datapath.
        let fastpath: Option<Arc<LocalFastPath>> = if spec.local_fastpath {
            let mut peers = HashMap::new();
            for k in spec.kernels.iter().filter(|k| hosted.contains(&k.node)) {
                if spec.node(k.node)?.platform == Platform::Sw {
                    let ks = kstate.get(&k.id).expect("hosted kernel state exists");
                    peers.insert(
                        k.id,
                        LocalPeer {
                            node: k.node,
                            segment: ks.segment.clone(),
                            handlers: Arc::clone(&ks.handlers),
                            medium_tx: ks.medium_tx.clone(),
                        },
                    );
                }
            }
            if peers.is_empty() {
                None
            } else {
                Some(LocalFastPath::new(peers))
            }
        } else {
            None
        };

        // Send-failure sink: when a transport gives up on a wire message (a
        // failed batch flush, or reliable-UDP retries exhausting), the exact
        // operation that sent it must fail — its token names the completion
        // entry in the issuing kernel's table. Installed on every hosted
        // node's transport before start.
        let completions: HashMap<u16, Arc<CompletionTable>> =
            kstate.iter().map(|(kid, ks)| (*kid, Arc::clone(&ks.completion))).collect();
        let sink: crate::galapagos::transport::SendFailureSink =
            Arc::new(move |pkt: &crate::galapagos::packet::Packet, reason: &str| {
                match crate::am::header::AmMessage::decode(&pkt.data) {
                    Ok(m) if m.flags.is_handle() && m.token != 0 => {
                        // A lost request fails the sender's operation; a
                        // lost REPLY fails the requester's — the reply
                        // echoes the request's token, and the requester
                        // (pkt.dest) may well be hosted in this process
                        // (single-process multi-node clusters). A remote
                        // owner simply isn't in the map and falls back to
                        // its own timeout.
                        let owner = if m.flags.is_reply() { pkt.dest } else { pkt.src };
                        if let Some(table) = completions.get(&owner) {
                            // Dead-peer fencing reports through the same
                            // sink with a structured reason string; decode
                            // it so the handle fails with `Error::PeerDead`
                            // naming the peer instead of a generic string.
                            match crate::galapagos::health::parse_dead_peer(reason) {
                                Some((node, detail)) => {
                                    table.fail_token_peer_dead(m.token, node, detail)
                                }
                                None => table.fail_token(m.token, reason),
                            }
                        }
                    }
                    // Async sends and collective fan messages carry no
                    // handle token; their loss is covered by the
                    // collective/barrier straggler timeouts.
                    _ => {}
                }
            });

        // Phase 2: start nodes with platform-appropriate delivery, spawn the
        // runtime components.
        let mut nodes = Vec::new();
        let mut handler_threads = Vec::new();
        let mut gascores = Vec::new();
        let mut adapters: Vec<JoinHandle<()>> = Vec::new();
        let mut routers: HashMap<u16, RouterHandle> = HashMap::new();

        for mut b in bound {
            b.set_failure_sink(Arc::clone(&sink));
            // When the node runs a failure detector, a peer's death must
            // reach the collectives layer: abort every in-flight collective
            // whose tree includes a kernel on the dead node (the straggler
            // timeout would eventually fire, but the detector knows *now*),
            // and poison future begins so they fail at issue. The ledger
            // inside each CollectiveState records the membership epoch.
            if let Some(h) = b.health() {
                let spec_for_deaths = Arc::clone(&spec);
                let collectives: Vec<Arc<CollectiveState>> =
                    kstate.values().map(|ks| Arc::clone(&ks.collective)).collect();
                h.set_death_sink(Arc::new(move |node, epoch, detail| {
                    let dead_kernels = spec_for_deaths.kernels_on(node);
                    for c in &collectives {
                        c.abort_for_dead_kernels(&dead_kernels, node, epoch, detail);
                    }
                }));
            }
            let node_id = b.node_id();
            let platform = spec.node(node_id)?.platform;
            let local_kernels = spec.kernels_on(node_id);
            let peer_addrs: HashMap<u16, String> = addrs
                .iter()
                .filter(|(id, _)| **id != node_id)
                .map(|(id, a)| (*id, a.clone()))
                .collect();

            let make_rt = |kid: u16, ks: &KState| KernelRuntime {
                kernel_id: kid,
                segment: ks.segment.clone(),
                completion: Arc::clone(&ks.completion),
                barrier: Arc::clone(&ks.barrier),
                handlers: Arc::clone(&ks.handlers),
                collective: Arc::clone(&ks.collective),
                medium_tx: ks.medium_tx.clone(),
            };

            match platform {
                Platform::Sw => {
                    // One delivery channel and handler thread per kernel.
                    let mut delivery = HashMap::new();
                    let mut pending = Vec::new();
                    for &kid in &local_kernels {
                        let (tx, rx) = mpsc::channel();
                        delivery.insert(kid, tx);
                        pending.push((kid, rx));
                    }
                    let node = b.start_with_delivery(peer_addrs, &fabric, delivery)?;
                    let router = node.router_handle();
                    for (kid, rx) in pending {
                        let ks = kstate.get(&kid).unwrap();
                        handler_threads.push(HandlerThread::spawn(
                            make_rt(kid, ks),
                            rx,
                            router.clone(),
                        ));
                    }
                    routers.insert(node_id, router);
                    nodes.push(node);
                }
                Platform::Hw => {
                    // All kernels share the GAScore's single ingress channel.
                    let (tx, rx) = mpsc::channel();
                    let delivery: HashMap<u16, _> =
                        local_kernels.iter().map(|&kid| (kid, tx.clone())).collect();
                    let node = b.start_with_delivery(peer_addrs, &fabric, delivery)?;
                    let runtimes: Vec<KernelRuntime> = local_kernels
                        .iter()
                        .map(|&kid| make_rt(kid, kstate.get(&kid).unwrap()))
                        .collect();
                    let gascore =
                        GAScoreServer::spawn(node_id, runtimes, rx, node.router_handle());

                    // Hardware kernels send through the GAScore's
                    // "From Kernels" interface (§III-C egress step 1), not
                    // straight to the router: adapt the kernel-facing
                    // RouterMsg channel onto the GAScore stream.
                    let kernel_tx = gascore.kernel_tx();
                    let (adapter_tx, adapter_rx) = mpsc::channel();
                    let adapter = std::thread::Builder::new()
                        .name(format!("gascore-egress-n{node_id}"))
                        .spawn(move || {
                            while let Ok(msg) = adapter_rx.recv() {
                                if let crate::galapagos::router::RouterMsg::FromKernel(pkt) = msg
                                {
                                    if kernel_tx
                                        .send(crate::gascore::server::GAScoreMsg::FromKernels(
                                            pkt,
                                        ))
                                        .is_err()
                                    {
                                        break;
                                    }
                                }
                            }
                        })
                        .expect("spawn gascore egress adapter");
                    adapters.push(adapter);

                    gascores.push(gascore);
                    routers.insert(node_id, RouterHandle::single(adapter_tx));
                    nodes.push(node);
                }
            }
        }

        // Build the API handles (hosted kernels only). Hardware kernels do
        // not get the fast path: their sends must flow through the GAScore
        // egress pipeline so cycle accounting and stats stay faithful.
        let mut kernels = HashMap::new();
        for k in spec.kernels.iter().filter(|k| hosted.contains(&k.node)) {
            let ks = kstate.get_mut(&k.id).unwrap();
            let router = routers
                .get(&k.node)
                .ok_or(Error::UnknownNode(k.node))?
                .clone();
            let fp = match spec.node(k.node)?.platform {
                Platform::Sw => fastpath.clone(),
                Platform::Hw => None,
            };
            kernels.insert(
                k.id,
                ShoalKernel::new(
                    k.id,
                    k.node,
                    Arc::clone(&spec),
                    router,
                    ks.segment.clone(),
                    Arc::clone(&ks.completion),
                    Arc::clone(&ks.barrier),
                    Arc::clone(&ks.handlers),
                    Arc::clone(&ks.collective),
                    ks.medium_rx.take().expect("medium receiver claimed once"),
                    fp,
                ),
            );
        }

        Ok(ShoalCluster {
            spec,
            nodes,
            handler_threads,
            gascores,
            adapters,
            kernels: Mutex::new(kernels),
            tables,
            running: Mutex::new(Vec::new()),
        })
    }

    /// The cluster description.
    pub fn spec(&self) -> &ClusterSpec {
        &self.spec
    }

    /// Claim a kernel's API handle to drive it from the current thread.
    pub fn take_kernel(&self, id: u16) -> Result<ShoalKernel> {
        self.kernels
            .lock()
            .unwrap()
            .remove(&id)
            .ok_or(Error::UnknownKernel(id))
    }

    /// Start kernel `id`'s kernel function on its own thread (the
    /// libGalapagos model). Panics in kernel functions are surfaced by
    /// [`ShoalCluster::join`].
    pub fn run_kernel(&self, id: u16, f: impl FnOnce(ShoalKernel) + Send + 'static) {
        let k = self.take_kernel(id).expect("kernel already claimed or unknown");
        let handle = std::thread::Builder::new()
            .name(format!("kernel-{id}"))
            .spawn(move || f(k))
            .expect("spawn kernel thread");
        self.running.lock().unwrap().push((id, handle));
    }

    /// Register a user handler on a (software) kernel before running it.
    pub fn register_handler(
        &self,
        kernel: u16,
        id: u8,
        f: impl Fn(crate::am::handlers::HandlerArgs<'_>) + Send + Sync + 'static,
    ) -> Result<()> {
        let t = self.tables.get(&kernel).ok_or(Error::UnknownKernel(kernel))?;
        t.register(id, Box::new(f))
    }

    /// GAScore statistics for a hardware node (cycle and message counts).
    pub fn gascore_stats(&self, node_id: u16) -> Option<Arc<GAScoreStats>> {
        self.gascores
            .iter()
            .find(|g| g.node_id() == node_id)
            .map(|g| g.stats())
    }

    /// Router statistics for a node, summed across its shards.
    pub fn router_stats(&self, node_id: u16) -> Option<crate::galapagos::router::RouterStats> {
        self.nodes
            .iter()
            .find(|n| n.node_id == node_id)
            .map(|n| n.stats())
    }

    /// A hosted node's failure detector, if heartbeats are configured and
    /// the transport supports them.
    pub fn peer_health(
        &self,
        node_id: u16,
    ) -> Option<Arc<crate::galapagos::health::PeerHealth>> {
        self.nodes.iter().find(|n| n.node_id == node_id).and_then(|n| n.health())
    }

    /// Wait for all kernel threads started by `run_kernel`, then tear down
    /// the runtime. Returns an error naming the first kernel that panicked.
    pub fn join(mut self) -> Result<()> {
        let mut failed: Option<u16> = None;
        for (id, h) in self.running.lock().unwrap().drain(..) {
            if h.join().is_err() && failed.is_none() {
                failed = Some(id);
            }
        }
        // Drop the unclaimed kernel handles (closes their router senders).
        self.kernels.lock().unwrap().clear();
        // Shut down routers; delivery senders drop, so handler threads and
        // GAScores see disconnects and exit.
        for n in &mut self.nodes {
            n.shutdown();
        }
        for ht in &mut self.handler_threads {
            ht.join();
        }
        // Egress adapters exit once every hardware kernel handle is gone.
        for a in self.adapters.drain(..) {
            let _ = a.join();
        }
        for g in &mut self.gascores {
            g.join();
        }
        match failed {
            Some(id) => Err(Error::Config(format!("kernel {id} panicked"))),
            None => Ok(()),
        }
    }
}
