//! Intra-node one-sided fast path.
//!
//! The PGAS pitch is that the local/remote distinction is cheap (paper §III,
//! Figs. 4/6) — yet historically a put between two kernels of the *same*
//! node paid the full codec + router + handler-thread round trip. GAScore's
//! one-sided hardware puts and DART-MPI's shared-memory window both show
//! where a PGAS runtime earns its latency: a local put is just a memcpy into
//! the target segment.
//!
//! `LocalFastPath` is the registry that makes that possible: one
//! [`LocalPeer`] per software kernel hosted in this `ShoalCluster` process,
//! carrying the three things a one-sided operation needs — the target
//! [`Segment`], the handler table (to decide whether a notification AM must
//! still fire), and the kernel-stream sender (for Medium deliveries).
//!
//! Semantics (documented in README "Zero-copy datapath"):
//!
//! - Only **same-node software** kernels are eligible: hardware kernels keep
//!   the GAScore ingress path (cycle accounting, stats), and cross-node
//!   kernels keep the transport, so the wire format and remote-visible
//!   behavior are bitwise unchanged.
//! - One-sided puts with a **registered user handler** still fire it: the
//!   data is written directly, then a payload-free notification AM (a Short
//!   with the same handler id and args; one per chunk, matching the wire
//!   path's per-chunk dispatch count) is enqueued through the router so the
//!   handler runs on the destination's handler thread, after the data is
//!   visible. An *unregistered* user-range handler id forces the slow path,
//!   preserving the engine's `UnknownHandler` behavior.
//! - Medium puts with a registered user handler take the slow path entirely
//!   (the handler's contract includes the payload).
//! - Gets never dispatch handlers (matching the ingress engine), so they are
//!   always eligible.

use std::collections::HashMap;
use std::sync::mpsc::Sender;
use std::sync::Arc;

use crate::am::engine::ReceivedMedium;
use crate::am::handlers::HandlerTable;
use crate::am::types::handler_ids;
use crate::error::{Error, Result};
use crate::memory::Segment;

/// Everything a one-sided local operation needs from its target kernel.
pub(crate) struct LocalPeer {
    /// Node hosting the kernel (fast path is intra-node only).
    pub node: u16,
    /// The kernel's partition of the global address space.
    pub segment: Segment,
    /// The kernel's handler table (notification decision).
    pub handlers: Arc<HandlerTable>,
    /// The kernel's Medium stream (direct deliveries).
    pub medium_tx: Sender<ReceivedMedium>,
}

/// How a one-sided put to a peer must honor handler semantics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum PutDisposition {
    /// Built-in handler id: puts never dispatch it — write directly.
    Direct,
    /// Registered user handler: write directly, then enqueue the
    /// payload-free notification AM.
    Notify,
    /// Unregistered user-range handler id: take the slow path so the
    /// engine's `UnknownHandler` semantics are preserved.
    SlowPath,
}

impl LocalPeer {
    /// Handler semantics for a one-sided put toward this peer.
    pub(crate) fn put_disposition(&self, handler: u8) -> PutDisposition {
        if handler < handler_ids::USER_BASE {
            PutDisposition::Direct
        } else if self.handlers.has(handler) {
            PutDisposition::Notify
        } else {
            PutDisposition::SlowPath
        }
    }

    /// True when a Medium put may bypass the router: built-in handler ids
    /// only (a registered user handler needs the payload on the handler
    /// thread; an unregistered user id must keep the engine's error path).
    pub(crate) fn medium_put_direct(&self, handler: u8) -> bool {
        handler < handler_ids::USER_BASE
    }

    /// Deliver a Medium payload straight onto this peer's kernel stream —
    /// the single copy of the local Medium path (caller slice → stream).
    // shoal-lint: hotpath
    pub(crate) fn deliver_medium(
        &self,
        src: u16,
        handler: u8,
        token: u32,
        args: &[u64],
        payload: &[u8],
    ) -> Result<()> {
        self.deliver_medium_owned(src, handler, token, args, payload.to_vec())
    }

    /// `deliver_medium` moving an already-owned payload (the `from_mem`
    /// path's segment read goes straight into the stream without re-copying).
    // shoal-lint: hotpath
    pub(crate) fn deliver_medium_owned(
        &self,
        src: u16,
        handler: u8,
        token: u32,
        args: &[u64],
        payload: Vec<u8>,
    ) -> Result<()> {
        self.medium_tx
            .send(ReceivedMedium { src, handler, token, args: args.to_vec(), payload })
            .map_err(|_| Error::Disconnected("kernel medium stream"))
    }

    /// Serve a local Medium get: read this peer's segment and deliver onto
    /// the *requesting* kernel's stream, mirroring the wire data reply
    /// (src = responder, args = [chunk offset]).
    // 8 params: the flat get-request descriptor (requester, handler,
    // token, source range, chunk) — a struct would outlive this one caller.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn serve_medium_get(
        &self,
        requester: &LocalPeer,
        responder_id: u16,
        handler: u8,
        token: u32,
        src_addr: u64,
        len: usize,
        chunk_off: u64,
    ) -> Result<()> {
        let payload = self.segment.read(src_addr, len)?;
        requester
            .medium_tx
            .send(ReceivedMedium {
                src: responder_id,
                handler,
                token,
                args: vec![chunk_off],
                payload,
            })
            .map_err(|_| Error::Disconnected("kernel medium stream"))
    }
}

/// Per-process registry of fast-path-eligible (software) kernels, shared by
/// every `ShoalKernel` the cluster hands out.
pub struct LocalFastPath {
    peers: HashMap<u16, LocalPeer>,
}

impl LocalFastPath {
    pub(crate) fn new(peers: HashMap<u16, LocalPeer>) -> Arc<LocalFastPath> {
        Arc::new(LocalFastPath { peers })
    }

    /// The peer entry for `dst` iff it shares `node` with the sender —
    /// intra-node only, so transports (and their benchmarks) never lose
    /// traffic to the fast path.
    pub(crate) fn peer(&self, node: u16, dst: u16) -> Option<&LocalPeer> {
        self.peers.get(&dst).filter(|p| p.node == node)
    }
}
