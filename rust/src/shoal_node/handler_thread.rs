//! The software handler thread (paper §III-B).
//!
//! "We add a handler thread for each software kernel. The handler thread is
//! responsible for tasks such as parsing headers, redirecting data to memory
//! or to kernels, and calling handler functions. It serves as the gatekeeper
//! between a particular kernel and the wider network."
//!
//! The thread drains the kernel's delivery channel, runs the shared AM
//! engine, and sends any generated replies back through the node router.
//! Ingress replies resolve the kernel's completion table inside the engine,
//! so a blocked `wait(handle)` on the kernel thread wakes the moment this
//! thread processes the matching reply packet.

use std::sync::mpsc::Receiver;
use std::thread::JoinHandle;

use crate::am::engine::KernelRuntime;
use crate::galapagos::packet::Packet;
use crate::galapagos::router::RouterHandle;
use crate::am::header::AmMessage;

/// Handle to a running handler thread.
pub struct HandlerThread {
    handle: Option<JoinHandle<()>>,
}

impl HandlerThread {
    /// Spawn the gatekeeper for one software kernel. Exits when the delivery
    /// channel disconnects (node shutdown).
    pub fn spawn(rt: KernelRuntime, inbox: Receiver<Packet>, router: RouterHandle) -> Self {
        let kernel_id = rt.kernel_id;
        let handle = std::thread::Builder::new()
            .name(format!("handler-k{kernel_id}"))
            .spawn(move || {
                while let Ok(pkt) = inbox.recv() {
                    // decode_owned reuses the packet buffer for the payload
                    // (single-copy ingress, §Perf).
                    let msg = match AmMessage::decode_owned(pkt.data) {
                        Ok(m) => m,
                        Err(e) => {
                            log::warn!("handler k{kernel_id}: dropping malformed AM: {e}");
                            continue;
                        }
                    };
                    let mut emit_err = None;
                    let res = rt.process_ingress(msg, &mut |reply| {
                        match reply
                            .encode()
                            .and_then(|bytes| Packet::new(reply.dst, reply.src, bytes))
                        {
                            Ok(p) => {
                                if router.from_kernel(p).is_err() {
                                    emit_err = Some("router disconnected");
                                }
                            }
                            Err(e) => {
                                log::error!("handler k{kernel_id}: cannot encode reply: {e}")
                            }
                        }
                    });
                    if let Err(e) = res {
                        log::warn!("handler k{kernel_id}: ingress error: {e}");
                    }
                    if emit_err.is_some() {
                        break;
                    }
                }
                log::debug!("handler k{kernel_id}: exiting");
            })
            .expect("spawn handler thread");
        HandlerThread { handle: Some(handle) }
    }

    /// Wait for the thread to exit (after its channels disconnect).
    pub fn join(&mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::am::completion::CompletionTable;
    use crate::galapagos::router::RouterMsg;
    use crate::am::engine::BarrierState;
    use crate::am::handlers::HandlerTable;
    use crate::collectives::CollectiveState;
    use crate::am::types::{handler_ids, AmFlags, AmType};
    use crate::am::Descriptor;
    use crate::memory::Segment;
    use std::sync::mpsc;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn processes_packets_and_replies() {
        let (medium_tx, medium_rx) = mpsc::channel();
        let completion = CompletionTable::new();
        let rt = KernelRuntime {
            kernel_id: 1,
            segment: Segment::new(1024),
            collective: CollectiveState::new(1, vec![1], Arc::clone(&completion)),
            completion,
            barrier: BarrierState::new(),
            handlers: Arc::new(HandlerTable::software()),
            medium_tx,
        };
        let (inbox_tx, inbox_rx) = mpsc::channel();
        let (router_tx, router_rx) = mpsc::channel();
        let mut ht = HandlerThread::spawn(rt, inbox_rx, RouterHandle::single(router_tx));

        let msg = AmMessage {
            am_type: AmType::Medium,
            flags: AmFlags::new(),
            src: 0,
            dst: 1,
            handler: handler_ids::NOP,
            token: 5,
            args: vec![],
            desc: Descriptor::None,
            payload: vec![1, 2],
        };
        let pkt = Packet::new(1, 0, msg.encode().unwrap()).unwrap();
        inbox_tx.send(pkt).unwrap();

        // Medium reaches the kernel stream.
        let got = medium_rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(got.payload, vec![1, 2]);

        // Ack goes back through the router.
        match router_rx.recv_timeout(Duration::from_secs(1)).unwrap() {
            RouterMsg::FromKernel(p) => {
                let reply = AmMessage::decode(&p.data).unwrap();
                assert!(reply.flags.is_reply());
                assert_eq!(reply.dst, 0);
                assert_eq!(reply.token, 5);
            }
            other => panic!("unexpected {other:?}"),
        }

        drop(inbox_tx);
        ht.join();
    }

    #[test]
    fn handle_reply_resolves_table_through_thread() {
        let (medium_tx, _medium_rx) = mpsc::channel();
        let completion = CompletionTable::new();
        let rt = KernelRuntime {
            kernel_id: 1,
            segment: Segment::new(64),
            collective: CollectiveState::new(1, vec![1], Arc::clone(&completion)),
            completion: Arc::clone(&completion),
            barrier: BarrierState::new(),
            handlers: Arc::new(HandlerTable::software()),
            medium_tx,
        };
        let (inbox_tx, inbox_rx) = mpsc::channel();
        let (router_tx, _router_rx) = mpsc::channel();
        let mut ht = HandlerThread::spawn(rt, inbox_rx, RouterHandle::single(router_tx));

        // Register an operation the way the API does, then feed its reply in
        // through the network-delivery channel.
        let h = completion.create(1);
        let token = completion.bind_token(h);
        let reply = AmMessage {
            am_type: AmType::Short,
            flags: AmFlags::new().with(AmFlags::REPLY).with(AmFlags::HANDLE),
            src: 0,
            dst: 1,
            handler: handler_ids::REPLY,
            token,
            args: vec![],
            desc: Descriptor::None,
            payload: vec![],
        };
        inbox_tx.send(Packet::new(1, 0, reply.encode().unwrap()).unwrap()).unwrap();

        completion.wait(h, Duration::from_secs(2)).unwrap();
        assert_eq!(completion.resolved_total(), 1);
        drop(inbox_tx);
        ht.join();
    }

    #[test]
    fn malformed_packets_are_dropped_not_fatal() {
        let (medium_tx, medium_rx) = mpsc::channel();
        let completion = CompletionTable::new();
        let rt = KernelRuntime {
            kernel_id: 1,
            segment: Segment::new(64),
            collective: CollectiveState::new(1, vec![1], Arc::clone(&completion)),
            completion,
            barrier: BarrierState::new(),
            handlers: Arc::new(HandlerTable::software()),
            medium_tx,
        };
        let (inbox_tx, inbox_rx) = mpsc::channel();
        let (router_tx, _router_rx) = mpsc::channel();
        let mut ht = HandlerThread::spawn(rt, inbox_rx, RouterHandle::single(router_tx));

        inbox_tx.send(Packet::new(1, 0, vec![0xFF; 3]).unwrap()).unwrap();
        // A valid message afterwards still gets through.
        let msg = AmMessage {
            am_type: AmType::Medium,
            flags: AmFlags::new().with(AmFlags::ASYNC),
            src: 0,
            dst: 1,
            handler: handler_ids::NOP,
            token: 0,
            args: vec![],
            desc: Descriptor::None,
            payload: vec![9],
        };
        inbox_tx.send(Packet::new(1, 0, msg.encode().unwrap()).unwrap()).unwrap();
        assert_eq!(
            medium_rx.recv_timeout(Duration::from_secs(1)).unwrap().payload,
            vec![9]
        );
        drop(inbox_tx);
        ht.join();
    }
}
