//! The Shoal runtime: nodes, kernels and the user API.
//!
//! - [`api`] — `ShoalKernel`: the heterogeneous communication API (paper
//!   §III-A). The same calls drive software kernels (threads) and hardware
//!   kernels (GAScore-backed simulated FPGA kernels).
//! - [`handler_thread`] — the per-kernel gatekeeper on software nodes
//!   (paper §III-B).
//! - [`cluster`] — `ShoalCluster`: launches every node of a `ClusterSpec`
//!   in-process (routers, transports, handler threads, GAScores) and runs
//!   user kernel functions on threads, mirroring how libGalapagos starts a
//!   kernel function per thread.
//! - [`fastpath`] — the intra-node one-sided fast path: puts/gets between
//!   same-node software kernels write/read the target segment directly and
//!   bypass codec + router.
//! - [`rma`] — `Rma`: the typed one-sided tier (put/get/atomics against a
//!   `GlobalAddress` with per-op `OpOptions`), lowered entirely onto the
//!   `am_*` builders.

pub mod api;
pub mod cluster;
pub mod fastpath;
pub mod handler_thread;
pub mod rma;
