//! The typed one-sided tier: `Rma` over [`ShoalKernel`].
//!
//! The raw `am_*` builders expose the paper's active-message surface —
//! handler ids, arg vectors, FIFO-vs-memory payload sourcing — which is the
//! right tier when the destination runs a handler. But PGAS *data movement*
//! (put/get/atomic against a global address) doesn't want a handler id or an
//! `_async`/`_from_mem` suffix matrix; it wants an address and options. The
//! `Rma` facade is that tier:
//!
//! | `Rma` call     | lowers to                              | class      |
//! |----------------|----------------------------------------|------------|
//! | `put`          | `am_long` / `am_long_async`            | Long       |
//! | `put_from`     | `am_long_from_mem` (+ ASYNC flag)      | Long       |
//! | `get`          | `am_long_get`                          | Long get   |
//! | `faa`          | `am_atomic` (FAA family)               | Atomic     |
//! | `cas` / `swap` | `am_atomic`                            | Atomic     |
//! | `accumulate`   | `am_accumulate` / `am_accumulate_async`| Atomic     |
//!
//! Every method is implemented **entirely over the existing builders** — the
//! wire format and remote-visible behavior are bitwise identical to calling
//! the `am_*` tier directly. [`OpOptions`] replaces the suffix matrix:
//! completion mode (tracked handle vs. fire-and-forget), chunking override,
//! and datapath locality are per-call options instead of per-variant
//! function names.
//!
//! Fetch atomics return a [`FetchHandle<T>`]: a completion handle whose
//! resolution carries the target word's pre-op value, extracted exactly once
//! with [`Rma::wait_fetch`]. A failed or lost atomic fails the owning handle
//! like any other tracked send. Fetch ops cannot be issued fire-and-forget
//! ([`Error::BadDescriptor`]) — with no reply there is nothing to fetch.

use std::marker::PhantomData;

use crate::am::completion::AmHandle;
use crate::am::types::{handler_ids, AmFlags, AtomicOp};
use crate::collectives::{Lane, ReduceOp};
use crate::config::ChunkPolicy;
use crate::error::{Error, Result};
use crate::shoal_node::api::ShoalKernel;

pub use crate::memory::GlobalAddress;

/// How an `Rma` operation completes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Completion {
    /// Tracked: the returned handle resolves when the target has acked (or
    /// at issue time on the intra-node fast path) and composes with
    /// `wait`/`test`/`wait_all`/`wait_any`.
    #[default]
    Handle,
    /// Fire-and-forget: no reply is generated and the returned handle is
    /// already complete. Not valid for gets or fetch atomics.
    Async,
}

/// Per-operation chunking control.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Chunk {
    /// Defer to the cluster's [`ChunkPolicy`].
    #[default]
    Auto,
    /// The transfer must fit one AM; a payload that would chunk is an
    /// [`Error::AmTooLarge`] before anything is sent.
    Single,
}

/// Per-operation datapath control.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Locality {
    /// Use the intra-node fast path when the destination is eligible.
    #[default]
    Auto,
    /// Always take the codec + router path, even to a same-node kernel
    /// (deterministic datapath for benchmarking and validation).
    Wire,
}

/// Options for one `Rma` operation — the typed replacement for the `am_*`
/// tier's `_async` variants and cluster-global knobs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpOptions {
    pub completion: Completion,
    pub chunk: Chunk,
    pub locality: Locality,
}

impl OpOptions {
    /// Fire-and-forget completion.
    pub fn fire_and_forget() -> OpOptions {
        OpOptions { completion: Completion::Async, ..OpOptions::default() }
    }

    /// Require the transfer to fit a single AM.
    pub fn single_message(mut self) -> OpOptions {
        self.chunk = Chunk::Single;
        self
    }

    /// Skip the intra-node fast path.
    pub fn force_wire(mut self) -> OpOptions {
        self.locality = Locality::Wire;
        self
    }
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for u64 {}
    impl Sealed for f64 {}
}

/// Types a fetch atomic can resolve to: the target's 8-byte little-endian
/// word, reinterpreted.
pub trait FetchValue: sealed::Sealed + Copy {
    fn from_word(w: u64) -> Self;
}

impl FetchValue for u64 {
    fn from_word(w: u64) -> u64 {
        w
    }
}

impl FetchValue for f64 {
    fn from_word(w: u64) -> f64 {
        f64::from_bits(w)
    }
}

/// A typed handle to an in-flight fetch atomic. `am` is an ordinary
/// completion handle (it composes with `wait_all`/`wait_any` fences); the
/// pre-op value itself is extracted exactly once with
/// [`Rma::wait_fetch`] (or [`ShoalKernel::wait_fetch`] on the raw tier).
#[derive(Clone, Copy, Debug)]
#[must_use = "the fetched value is only retrieved by waiting on the handle"]
pub struct FetchHandle<T: FetchValue> {
    pub am: AmHandle,
    _marker: PhantomData<T>,
}

/// The one-sided tier over a kernel; obtained from
/// [`ShoalKernel::rma`]. Borrows the kernel mutably, so interleave freely:
/// `k.rma().put(...)` then `k.wait(...)`.
pub struct Rma<'k> {
    k: &'k mut ShoalKernel,
}

impl<'k> Rma<'k> {
    pub(crate) fn new(k: &'k mut ShoalKernel) -> Rma<'k> {
        Rma { k }
    }

    /// Run `f` with the kernel's per-op overrides set from `opts`, restoring
    /// them after — options are strictly per-call.
    fn with_opts<T>(
        &mut self,
        opts: OpOptions,
        f: impl FnOnce(&mut ShoalKernel) -> Result<T>,
    ) -> Result<T> {
        let force_wire = self.k.force_wire;
        let chunk_override = self.k.chunk_override;
        self.k.force_wire = force_wire || opts.locality == Locality::Wire;
        self.k.chunk_override = match opts.chunk {
            Chunk::Auto => chunk_override,
            Chunk::Single => Some(ChunkPolicy::Reject),
        };
        let r = f(self.k);
        self.k.force_wire = force_wire;
        self.k.chunk_override = chunk_override;
        r
    }

    /// Put `data` at `dst` (a Long put with no handler side effects).
    pub fn put(&mut self, dst: GlobalAddress, data: &[u8], opts: OpOptions) -> Result<AmHandle> {
        self.with_opts(opts, |k| match opts.completion {
            Completion::Handle => k.am_long(dst.kernel, handler_ids::NOP, &[], data, dst.offset),
            Completion::Async => {
                k.am_long_async(dst.kernel, handler_ids::NOP, &[], data, dst.offset)
            }
        })
    }

    /// Put `len` bytes of this kernel's own partition (from `src_offset`)
    /// at `dst` — segment-to-segment, no intermediate buffer.
    pub fn put_from(
        &mut self,
        dst: GlobalAddress,
        src_offset: u64,
        len: usize,
        opts: OpOptions,
    ) -> Result<AmHandle> {
        let flags = match opts.completion {
            Completion::Handle => AmFlags::new(),
            Completion::Async => AmFlags::new().with(AmFlags::ASYNC),
        };
        self.with_opts(opts, |k| {
            k.long_from_mem_flags(dst.kernel, handler_ids::NOP, &[], src_offset, len, dst.offset, flags)
        })
    }

    /// Get `len` bytes at `src` into this kernel's partition at
    /// `local_offset`. A get always needs its data reply, so
    /// `Completion::Async` is rejected.
    pub fn get(
        &mut self,
        src: GlobalAddress,
        local_offset: u64,
        len: usize,
        opts: OpOptions,
    ) -> Result<AmHandle> {
        if opts.completion == Completion::Async {
            return Err(Error::BadDescriptor(
                "a get cannot be fire-and-forget: its completion is the data reply".into(),
            ));
        }
        self.with_opts(opts, |k| {
            k.am_long_get(src.kernel, handler_ids::NOP, src.offset, len, local_offset)
        })
    }

    /// Fetch-and-op on the word at `dst`: `op` must be of the FAA family
    /// (add/min/max/and/or/xor). Returns a typed handle resolving to the
    /// pre-op value.
    pub fn faa(
        &mut self,
        dst: GlobalAddress,
        op: AtomicOp,
        operand: u64,
        opts: OpOptions,
    ) -> Result<FetchHandle<u64>> {
        if matches!(op, AtomicOp::Cas | AtomicOp::Swap) || op.is_accumulate() {
            return Err(Error::BadDescriptor(format!("{op} is not a fetch-and-op")));
        }
        self.fetch(dst, op, operand, 0, opts)
    }

    /// Compare-and-swap: iff the word at `dst` equals `expected`, replace it
    /// with `desired`. The returned handle resolves to the observed value —
    /// the CAS succeeded iff it equals `expected`.
    pub fn cas(
        &mut self,
        dst: GlobalAddress,
        expected: u64,
        desired: u64,
        opts: OpOptions,
    ) -> Result<FetchHandle<u64>> {
        self.fetch(dst, AtomicOp::Cas, expected, desired, opts)
    }

    /// Unconditionally store `value` at `dst`, resolving to the old value.
    pub fn swap(
        &mut self,
        dst: GlobalAddress,
        value: u64,
        opts: OpOptions,
    ) -> Result<FetchHandle<u64>> {
        self.fetch(dst, AtomicOp::Swap, value, 0, opts)
    }

    fn fetch(
        &mut self,
        dst: GlobalAddress,
        op: AtomicOp,
        operand: u64,
        operand2: u64,
        opts: OpOptions,
    ) -> Result<FetchHandle<u64>> {
        if opts.completion == Completion::Async {
            return Err(Error::BadDescriptor(format!(
                "{op} fetches the old value; a fire-and-forget op has no reply to carry it \
                 (use accumulate for reply-free updates)"
            )));
        }
        let am = self.with_opts(opts, |k| {
            k.am_atomic(dst.kernel, dst.offset, op, operand, operand2)
        })?;
        Ok(FetchHandle { am, _marker: PhantomData })
    }

    /// Element-wise accumulate of `data` (8-byte `lane` lanes, reduction
    /// `op`) into the partition at `dst`. Fetches nothing; the handle is
    /// consumed with the plain wait family.
    pub fn accumulate(
        &mut self,
        dst: GlobalAddress,
        op: ReduceOp,
        lane: Lane,
        data: &[u8],
        opts: OpOptions,
    ) -> Result<AmHandle> {
        self.with_opts(opts, |k| match opts.completion {
            Completion::Handle => k.am_accumulate(dst.kernel, dst.offset, op, lane, data),
            Completion::Async => k.am_accumulate_async(dst.kernel, dst.offset, op, lane, data),
        })
    }

    /// Block until the fetch atomic completes and return the typed pre-op
    /// value. Extracted exactly once; a failed atomic surfaces as
    /// [`Error::OperationFailed`].
    pub fn wait_fetch<T: FetchValue>(&mut self, h: FetchHandle<T>) -> Result<T> {
        self.k.wait_fetch(h.am).map(T::from_word)
    }

    /// Block until `h` completes ([`ShoalKernel::wait`] convenience, so a
    /// put/accumulate sequence can stay on this tier).
    pub fn wait(&mut self, h: AmHandle) -> Result<()> {
        self.k.wait(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_options_builders_compose() {
        let o = OpOptions::fire_and_forget().single_message().force_wire();
        assert_eq!(o.completion, Completion::Async);
        assert_eq!(o.chunk, Chunk::Single);
        assert_eq!(o.locality, Locality::Wire);
        assert_eq!(OpOptions::default().completion, Completion::Handle);
    }

    #[test]
    fn fetch_values_reinterpret_the_word() {
        assert_eq!(u64::from_word(7), 7);
        assert_eq!(f64::from_word(1.5f64.to_bits()), 1.5);
    }
}
