//! Calibration constants for the cost model.
//!
//! Every constant is anchored either to a paper observation, a vendor
//! datasheet figure, or a measurement on this machine (the loopback
//! microbenchmarks in `bench::micro` — see EXPERIMENTS.md §Calibration).
//! Units are nanoseconds unless stated.

use crate::gascore::cycles::CycleModel;

/// Software-endpoint costs (Xeon-class server, Linux kernel stack).
#[derive(Clone, Copy, Debug)]
pub struct SwCosts {
    /// Shoal API call: AM encode + channel into the router
    /// (measured ~1–2 µs on the loopback build of this library).
    pub api_ns: f64,
    /// One libGalapagos router-thread hop: mutex/condvar wake + demux.
    /// The paper's flat ~40 µs SW-SW(same) round trip implies ~10–12 µs per
    /// hop with four hops per round trip (send/recv × request/reply).
    pub router_hop_ns: f64,
    /// Handler-thread processing: header parse, memory/stream redirect.
    pub handler_ns: f64,
    /// Kernel TCP stack, send side (syscall + segmentation).
    pub tcp_tx_ns: f64,
    /// Kernel TCP stack, receive side (interrupt + copy + wake).
    pub tcp_rx_ns: f64,
    /// Kernel UDP stack, send side — no connection state, cheaper than TCP.
    pub udp_tx_ns: f64,
    /// Kernel UDP stack, receive side.
    pub udp_rx_ns: f64,
    /// Per-byte copy cost through the software path (memcpy at ~20 GB/s).
    pub per_byte_ns: f64,
}

impl Default for SwCosts {
    fn default() -> Self {
        SwCosts {
            api_ns: 1_500.0,
            router_hop_ns: 12_000.0,
            handler_ns: 6_000.0,
            tcp_tx_ns: 15_000.0,
            tcp_rx_ns: 12_000.0,
            udp_tx_ns: 8_000.0,
            udp_rx_ns: 6_000.0,
            per_byte_ns: 0.05,
        }
    }
}

/// Hardware-endpoint costs beyond the GAScore cycle model.
#[derive(Clone, Copy, Debug)]
pub struct HwCosts {
    /// FPGA TCP offload core, send side (session lookup + header insert;
    /// fully pipelined cores add ~100 cycles at 200 MHz).
    pub tcp_core_tx_ns: f64,
    /// FPGA TCP offload core, receive side.
    pub tcp_core_rx_ns: f64,
    /// FPGA UDP core — stateless, a few dozen cycles.
    pub udp_core_tx_ns: f64,
    pub udp_core_rx_ns: f64,
    /// On-FPGA AXIS interconnect hop for same-node kernel traffic.
    pub axis_hop_ns: f64,
    /// Effective DRAM bandwidth for DataMover bursts (bytes/ns = GB/s).
    /// One DDR4-2400 channel minus refresh/arbitration ≈ 12 GB/s.
    pub dram_bytes_per_ns: f64,
}

impl Default for HwCosts {
    fn default() -> Self {
        HwCosts {
            tcp_core_tx_ns: 500.0,
            tcp_core_rx_ns: 500.0,
            udp_core_tx_ns: 150.0,
            udp_core_rx_ns: 150.0,
            axis_hop_ns: 100.0,
            dram_bytes_per_ns: 12.0,
        }
    }
}

/// Network fabric costs.
#[derive(Clone, Copy, Debug)]
pub struct NetCosts {
    /// Serialization at 10 Gb/s = 0.8 ns/byte.
    pub wire_ns_per_byte: f64,
    /// Store-and-forward latency of the S4048-ON (cut-through ~600 ns).
    pub switch_ns: f64,
    /// Fixed per-frame overhead (preamble + Ethernet/IP/TCP headers) in
    /// bytes, added to serialization.
    pub frame_overhead_bytes: f64,
    /// Ethernet MTU payload for the UDP fragmentation limit.
    pub mtu_payload: usize,
}

impl Default for NetCosts {
    fn default() -> Self {
        NetCosts {
            wire_ns_per_byte: 0.8,
            switch_ns: 600.0,
            frame_overhead_bytes: 78.0,
            mtu_payload: crate::galapagos::transport::udp::UDP_MTU_PAYLOAD,
        }
    }
}

/// The complete calibrated model.
#[derive(Clone, Copy, Debug, Default)]
pub struct CostModel {
    pub sw: SwCosts,
    pub hw: HwCosts,
    pub net: NetCosts,
    pub gascore: CycleModel,
}

impl CostModel {
    /// The paper's configuration.
    pub fn paper() -> Self {
        Self::default()
    }

    /// Variant with the tightly-integrated GAScore (§IV-B1 latency remark).
    pub fn tightly_integrated() -> Self {
        CostModel { gascore: CycleModel::tightly_integrated(), ..Default::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_rate_is_10g() {
        let n = NetCosts::default();
        // 0.8 ns/byte == 10 Gb/s
        let gbps = 8.0 / n.wire_ns_per_byte;
        assert!((gbps - 10.0).abs() < 1e-9);
    }

    #[test]
    fn sw_round_trip_same_node_is_tens_of_us() {
        // Sanity: 2×(api + router + handler) lands in the paper's flat
        // SW-SW(same) band (~30–50 µs).
        let s = SwCosts::default();
        let rt = 2.0 * (s.api_ns + s.router_hop_ns + s.handler_ns);
        assert!((25_000.0..60_000.0).contains(&rt), "{rt}");
    }

    #[test]
    fn udp_cheaper_than_tcp() {
        let s = SwCosts::default();
        assert!(s.udp_tx_ns < s.tcp_tx_ns);
        assert!(s.udp_rx_ns < s.tcp_rx_ns);
        let h = HwCosts::default();
        assert!(h.udp_core_tx_ns < h.tcp_core_tx_ns);
    }
}
