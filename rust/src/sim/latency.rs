//! End-to-end latency and throughput composition.
//!
//! A microbenchmark round trip is: Sender egress → network → Receiver
//! ingress → (handler) → reply egress → network → Sender ingress. The
//! composition below assigns each leg a cost from [`CostModel`], using the
//! GAScore cycle model for hardware endpoints and the calibrated software
//! constants otherwise. Unsupported points return `None` — exactly the
//! paper's missing UDP ≥ 2048 B hardware measurements (§IV-B1).

use super::costs::CostModel;
use super::topology::Topology;
use crate::am::header::{AmMessage, Descriptor};
use crate::am::types::{handler_ids, AmFlags, AmType};
use crate::config::Platform;

/// Network protocol between nodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Protocol {
    Tcp,
    Udp,
}

/// The AM variants the microbenchmarks sweep (paper §IV-B: "the different
/// types of AMs", averaged per topology in Figs. 4–6).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MsgKind {
    Short,
    MediumFifo,
    Medium,
    LongFifo,
    Long,
    LongStrided,
    LongVectored,
    MediumGet,
    LongGet,
}

impl MsgKind {
    /// The variants that carry a payload (payload-size sweeps apply).
    pub const PAYLOAD_KINDS: [MsgKind; 8] = [
        MsgKind::MediumFifo,
        MsgKind::Medium,
        MsgKind::LongFifo,
        MsgKind::Long,
        MsgKind::LongStrided,
        MsgKind::LongVectored,
        MsgKind::MediumGet,
        MsgKind::LongGet,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            MsgKind::Short => "short",
            MsgKind::MediumFifo => "medium-fifo",
            MsgKind::Medium => "medium",
            MsgKind::LongFifo => "long-fifo",
            MsgKind::Long => "long",
            MsgKind::LongStrided => "long-strided",
            MsgKind::LongVectored => "long-vectored",
            MsgKind::MediumGet => "medium-get",
            MsgKind::LongGet => "long-get",
        }
    }

    /// Build the request message this kind sends (payload of `p` bytes for
    /// put variants; gets request `p` bytes).
    pub fn request(&self, p: usize) -> AmMessage {
        let base = AmMessage {
            am_type: AmType::Short,
            flags: AmFlags::new(),
            src: 0,
            dst: 1,
            handler: handler_ids::NOP,
            token: 1,
            args: vec![],
            desc: Descriptor::None,
            payload: vec![],
        };
        match self {
            MsgKind::Short => base,
            MsgKind::MediumFifo => AmMessage {
                am_type: AmType::Medium,
                flags: AmFlags::new().with(AmFlags::FIFO),
                payload: vec![0; p],
                ..base
            },
            MsgKind::Medium => AmMessage {
                am_type: AmType::Medium,
                payload: vec![0; p],
                ..base
            },
            MsgKind::LongFifo => AmMessage {
                am_type: AmType::Long,
                flags: AmFlags::new().with(AmFlags::FIFO),
                desc: Descriptor::Long { dst_addr: 0 },
                payload: vec![0; p],
                ..base
            },
            MsgKind::Long => AmMessage {
                am_type: AmType::Long,
                desc: Descriptor::Long { dst_addr: 0 },
                payload: vec![0; p],
                ..base
            },
            MsgKind::LongStrided => {
                let block = 64.min(p.max(1)) as u32;
                AmMessage {
                    am_type: AmType::LongStrided,
                    flags: AmFlags::new().with(AmFlags::FIFO),
                    desc: Descriptor::Strided {
                        dst_addr: 0,
                        stride: block * 2,
                        block_len: block,
                        nblocks: (p as u32).div_ceil(block).max(1),
                    },
                    payload: vec![0; p],
                    ..base
                }
            }
            MsgKind::LongVectored => {
                let quarter = (p / 4).max(1) as u32;
                AmMessage {
                    am_type: AmType::LongVectored,
                    flags: AmFlags::new().with(AmFlags::FIFO),
                    desc: Descriptor::Vectored {
                        entries: (0..4u64).map(|i| (i * 2048, quarter)).collect(),
                    },
                    payload: vec![0; (quarter * 4) as usize],
                    ..base
                }
            }
            MsgKind::MediumGet => AmMessage {
                am_type: AmType::Medium,
                flags: AmFlags::new().with(AmFlags::GET),
                desc: Descriptor::MediumGet { src_addr: 0, len: p as u32 },
                ..base
            },
            MsgKind::LongGet => AmMessage {
                am_type: AmType::Long,
                flags: AmFlags::new().with(AmFlags::GET),
                desc: Descriptor::LongGet { src_addr: 0, len: p as u32, reply_addr: 0 },
                ..base
            },
        }
    }

    /// The reply message the request elicits.
    pub fn reply(&self, p: usize) -> AmMessage {
        let short_reply = AmMessage {
            am_type: AmType::Short,
            flags: AmFlags::new().with(AmFlags::REPLY),
            src: 1,
            dst: 0,
            handler: handler_ids::REPLY,
            token: 1,
            args: vec![],
            desc: Descriptor::None,
            payload: vec![],
        };
        match self {
            MsgKind::MediumGet => AmMessage {
                am_type: AmType::Medium,
                payload: vec![0; p],
                ..short_reply
            },
            MsgKind::LongGet => AmMessage {
                am_type: AmType::Long,
                desc: Descriptor::Long { dst_addr: 0 },
                payload: vec![0; p],
                ..short_reply
            },
            _ => short_reply,
        }
    }
}

impl CostModel {
    /// Egress cost at the sending endpoint.
    fn egress_ns(&self, platform: Platform, msg: &AmMessage, crosses_network: bool, proto: Protocol) -> f64 {
        let wire_len = (msg.header_overhead() + msg.payload.len()) as f64;
        match platform {
            Platform::Sw => {
                let mut t = self.sw.api_ns + self.sw.router_hop_ns + wire_len * self.sw.per_byte_ns;
                if crosses_network {
                    t += match proto {
                        Protocol::Tcp => self.sw.tcp_tx_ns,
                        Protocol::Udp => self.sw.udp_tx_ns,
                    };
                }
                t
            }
            Platform::Hw => {
                let mut t = self.gascore.to_ns(self.gascore.egress_cycles(msg));
                t += if crosses_network {
                    match proto {
                        Protocol::Tcp => self.hw.tcp_core_tx_ns,
                        Protocol::Udp => self.hw.udp_core_tx_ns,
                    }
                } else {
                    self.hw.axis_hop_ns
                };
                t
            }
        }
    }

    /// Ingress cost at the receiving endpoint. `generates_reply` matters to
    /// the GAScore model (reply creation in xpams_rx).
    fn ingress_ns(
        &self,
        platform: Platform,
        msg: &AmMessage,
        crosses_network: bool,
        proto: Protocol,
        generates_reply: bool,
    ) -> f64 {
        let wire_len = (msg.header_overhead() + msg.payload.len()) as f64;
        match platform {
            Platform::Sw => {
                let mut t = self.sw.handler_ns + wire_len * self.sw.per_byte_ns;
                if crosses_network {
                    t += self.sw.router_hop_ns; // router delivers transport ingress
                    t += match proto {
                        Protocol::Tcp => self.sw.tcp_rx_ns,
                        Protocol::Udp => self.sw.udp_rx_ns,
                    };
                }
                t
            }
            Platform::Hw => {
                let mut t = self.gascore.to_ns(self.gascore.ingress_cycles(msg, generates_reply));
                if crosses_network {
                    t += match proto {
                        Protocol::Tcp => self.hw.tcp_core_rx_ns,
                        Protocol::Udp => self.hw.udp_core_rx_ns,
                    };
                }
                // DRAM residence for Long payloads (DataMover burst).
                if msg.am_type.is_long() && !msg.payload.is_empty() {
                    t += msg.payload.len() as f64 / self.hw.dram_bytes_per_ns;
                }
                t
            }
        }
    }

    /// One wire crossing (switch + serialization), or `None` if the message
    /// cannot be carried (hardware UDP core + IP fragmentation, §IV-B1).
    fn network_ns(
        &self,
        msg: &AmMessage,
        proto: Protocol,
        endpoint_is_hw: [bool; 2],
    ) -> Option<f64> {
        let wire_len = msg.header_overhead() + msg.payload.len() + 8; // + middleware header
        if proto == Protocol::Udp
            && endpoint_is_hw.iter().any(|&h| h)
            && wire_len > self.net.mtu_payload
        {
            return None; // fragmented datagrams unsupported by the FPGA UDP core
        }
        Some(
            self.net.switch_ns
                + (wire_len as f64 + self.net.frame_overhead_bytes) * self.net.wire_ns_per_byte,
        )
    }

    /// Round-trip latency (request + reply) for one AM of `kind` with
    /// `payload` bytes over `topology`/`proto`. `None` when the combination
    /// is unsupported.
    pub fn latency_ns(
        &self,
        topo: Topology,
        proto: Protocol,
        kind: MsgKind,
        payload: usize,
    ) -> Option<f64> {
        let req = kind.request(payload);
        let rep = kind.reply(payload);
        let crosses = !topo.same_node();
        let s = topo.sender();
        let r = topo.receiver();
        let hw_pair = [s.is_hw(), r.is_hw()];

        let mut t = 0.0;
        t += self.egress_ns(s, &req, crosses, proto);
        if crosses {
            t += self.network_ns(&req, proto, hw_pair)?;
        }
        t += self.ingress_ns(r, &req, crosses, proto, true);
        // Reply leg.
        t += self.egress_ns(r, &rep, crosses, proto);
        if crosses {
            t += self.network_ns(&rep, proto, hw_pair)?;
        }
        t += self.ingress_ns(s, &rep, crosses, proto, false);
        Some(t)
    }

    /// Sustained throughput in bytes/second for pipelined non-blocking sends
    /// of `kind` ("the Sender sends all the messages in a loop and then
    /// waits for all the replies", §IV-B). Steady state is set by the
    /// slowest pipeline stage.
    pub fn throughput_bps(
        &self,
        topo: Topology,
        proto: Protocol,
        kind: MsgKind,
        payload: usize,
    ) -> Option<f64> {
        if payload == 0 {
            return Some(0.0);
        }
        let req = kind.request(payload);
        // Data flows on the reply leg for gets.
        let data_msg = if matches!(kind, MsgKind::MediumGet | MsgKind::LongGet) {
            kind.reply(payload)
        } else {
            req.clone()
        };
        let crosses = !topo.same_node();
        let (data_src, data_dst) = if matches!(kind, MsgKind::MediumGet | MsgKind::LongGet) {
            (topo.receiver(), topo.sender())
        } else {
            (topo.sender(), topo.receiver())
        };
        let hw_pair = [topo.sender().is_hw(), topo.receiver().is_hw()];

        // Per-message occupancy of each pipeline stage.
        let mut stages: Vec<f64> = Vec::with_capacity(4);
        stages.push(self.egress_ns(data_src, &data_msg, crosses, proto));
        if crosses {
            // The switch is cut-through: occupancy is serialization only.
            let wire_len = data_msg.header_overhead() + data_msg.payload.len() + 8;
            if proto == Protocol::Udp
                && hw_pair.iter().any(|&h| h)
                && wire_len > self.net.mtu_payload
            {
                return None;
            }
            stages.push(
                (wire_len as f64 + self.net.frame_overhead_bytes) * self.net.wire_ns_per_byte,
            );
        }
        stages.push(self.ingress_ns(data_dst, &data_msg, crosses, proto, true));
        let bottleneck = stages.iter().cloned().fold(0.0f64, f64::max);
        Some(payload as f64 / bottleneck * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PAYLOADS: [usize; 10] = [8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096];

    fn m() -> CostModel {
        CostModel::paper()
    }

    /// Average latency over payload-carrying AM kinds (what Fig. 4 plots).
    fn avg_latency(topo: Topology, proto: Protocol, p: usize) -> Option<f64> {
        let mut sum = 0.0;
        for k in MsgKind::PAYLOAD_KINDS {
            sum += m().latency_ns(topo, proto, k, p)?;
        }
        Some(sum / MsgKind::PAYLOAD_KINDS.len() as f64)
    }

    #[test]
    fn fig4_topology_ordering_holds() {
        // HW-HW(same) < HW-HW(diff) < SW-HW < SW-SW — the paper's core claim
        // "communication between kernels in hardware occurs much faster".
        for p in PAYLOADS {
            let hh_same = avg_latency(Topology::HwHwSame, Protocol::Tcp, p).unwrap();
            let hh_diff = avg_latency(Topology::HwHwDiff, Protocol::Tcp, p).unwrap();
            let sh = avg_latency(Topology::SwHw, Protocol::Tcp, p).unwrap();
            let ss_same = avg_latency(Topology::SwSwSame, Protocol::Tcp, p).unwrap();
            let ss_diff = avg_latency(Topology::SwSwDiff, Protocol::Tcp, p).unwrap();
            assert!(hh_same < hh_diff, "p={p}");
            assert!(hh_diff < sh, "p={p}");
            assert!(sh < ss_diff, "p={p}");
            assert!(hh_diff < ss_same, "p={p}: HW-HW(diff) {hh_diff} vs SW-SW(same) {ss_same}");
        }
    }

    #[test]
    fn fig4_sw_sw_same_is_flat() {
        // "SW-SW (same) shows a constant trend, indicating that there are
        // other overheads beyond the payload size."
        let small = avg_latency(Topology::SwSwSame, Protocol::Tcp, 8).unwrap();
        let large = avg_latency(Topology::SwSwSame, Protocol::Tcp, 4096).unwrap();
        assert!((large - small) / small < 0.10, "small={small} large={large}");
    }

    #[test]
    fn fig4_latency_grows_with_payload_elsewhere() {
        // Hardware topologies are dominated by streaming: strong growth.
        for topo in [Topology::HwHwSame, Topology::HwHwDiff] {
            let small = avg_latency(topo, Protocol::Tcp, 8).unwrap();
            let large = avg_latency(topo, Protocol::Tcp, 4096).unwrap();
            assert!(large > small * 1.2, "{topo}: {small} -> {large}");
        }
        // SW-HW grows too, but the software fixed costs damp the slope.
        let small = avg_latency(Topology::SwHw, Protocol::Tcp, 8).unwrap();
        let large = avg_latency(Topology::SwHw, Protocol::Tcp, 4096).unwrap();
        assert!(large > small * 1.05, "SW-HW: {small} -> {large}");
    }

    #[test]
    fn fig5_udp_speedup_over_tcp() {
        // "In most cases, messages sent with UDP are faster."
        for topo in [Topology::SwSwDiff, Topology::SwHw, Topology::HwHwDiff] {
            for p in [8, 256, 1024] {
                let t = avg_latency(topo, Protocol::Tcp, p).unwrap();
                let u = avg_latency(topo, Protocol::Udp, p).unwrap();
                assert!(u < t, "{topo} p={p}: udp {u} tcp {t}");
            }
        }
    }

    #[test]
    fn fig5_hw_udp_fragmentation_gap() {
        // "No data was collected for topologies including hardware for UDP
        // messages with 2048 and 4096 byte payload sizes."
        for topo in [Topology::SwHw, Topology::HwSw, Topology::HwHwDiff] {
            for p in [2048, 4096] {
                assert!(
                    avg_latency(topo, Protocol::Udp, p).is_none(),
                    "{topo} p={p} should be unsupported"
                );
            }
            assert!(avg_latency(topo, Protocol::Udp, 1024).is_some());
        }
        // Software-only topologies fragment fine in the kernel stack.
        assert!(avg_latency(Topology::SwSwDiff, Protocol::Udp, 4096).is_some());
    }

    #[test]
    fn fig6_throughput_shapes() {
        let tput = |topo, p| {
            let mut s = 0.0;
            for k in MsgKind::PAYLOAD_KINDS {
                s += m().throughput_bps(topo, Protocol::Tcp, k, p).unwrap();
            }
            s / MsgKind::PAYLOAD_KINDS.len() as f64
        };
        // Throughput rises with payload.
        for topo in Topology::ALL {
            assert!(tput(topo, 4096) > tput(topo, 8) * 10.0, "{topo}");
        }
        // HW ≫ SW.
        assert!(tput(Topology::HwHwSame, 4096) > 4.0 * tput(Topology::SwSwSame, 4096));
        // At 4096 B, HW-HW(diff) approaches HW-HW(same) (within ~40%).
        let same = tput(Topology::HwHwSame, 4096);
        let diff = tput(Topology::HwHwDiff, 4096);
        assert!(diff > 0.6 * same, "same={same} diff={diff}");
    }

    #[test]
    fn get_latency_includes_data_on_reply() {
        // A LongGet's reply carries the payload: large gets cost more than
        // small ones even though the request is tiny.
        let small = m().latency_ns(Topology::HwHwDiff, Protocol::Tcp, MsgKind::LongGet, 8).unwrap();
        let large =
            m().latency_ns(Topology::HwHwDiff, Protocol::Tcp, MsgKind::LongGet, 4096).unwrap();
        assert!(large > small * 1.5);
    }

    #[test]
    fn tightly_integrated_reduces_hw_latency() {
        let paper = CostModel::paper();
        let tight = CostModel::tightly_integrated();
        let p = paper
            .latency_ns(Topology::HwHwSame, Protocol::Tcp, MsgKind::MediumFifo, 64)
            .unwrap();
        let t = tight
            .latency_ns(Topology::HwHwSame, Protocol::Tcp, MsgKind::MediumFifo, 64)
            .unwrap();
        assert!(t < p);
    }
}
