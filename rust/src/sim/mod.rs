//! Discrete-event cost model for the evaluation figures.
//!
//! The paper's testbed (Xeon E5-2650 servers, Alpha Data 8K5 FPGAs, a Dell
//! S4048-ON 10G switch) is not available, so the latency/throughput figures
//! are regenerated from a first-order cost model composed of:
//!
//! - the GAScore cycle model ([`crate::gascore::cycles`]) for hardware
//!   endpoints,
//! - per-stage software costs (API, libGalapagos router hop, kernel TCP/UDP
//!   stack) calibrated in [`costs`],
//! - a network model (10 Gb/s serialization, switch hop, FPGA TCP/UDP
//!   offload cores, the no-IP-fragmentation UDP restriction).
//!
//! Functional behaviour always runs through the real library; this module
//! only assigns *time*. Every constant carries a doc comment citing the
//! paper observation or measurement anchoring it; `EXPERIMENTS.md` compares
//! the resulting curves with the paper's.

pub mod costs;
pub mod latency;
pub mod topology;

pub use costs::CostModel;
pub use latency::{MsgKind, Protocol};
pub use topology::Topology;
