//! The six placement topologies of the microbenchmarks (paper §IV-B):
//! "software-to-software (same node), software-to-software (different
//! nodes), software-to-hardware, hardware-to-hardware (same node) and
//! hardware-to-hardware (different nodes)" — six combinations including the
//! hardware-to-software direction.

use crate::config::Platform;

/// Where the Sender and Receiver kernels live.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Topology {
    SwSwSame,
    SwSwDiff,
    SwHw,
    HwSw,
    HwHwSame,
    HwHwDiff,
}

impl Topology {
    /// All six, in the order the paper's figures present them.
    pub const ALL: [Topology; 6] = [
        Topology::SwSwSame,
        Topology::SwSwDiff,
        Topology::SwHw,
        Topology::HwSw,
        Topology::HwHwSame,
        Topology::HwHwDiff,
    ];

    pub fn sender(&self) -> Platform {
        match self {
            Topology::SwSwSame | Topology::SwSwDiff | Topology::SwHw => Platform::Sw,
            _ => Platform::Hw,
        }
    }

    pub fn receiver(&self) -> Platform {
        match self {
            Topology::SwSwSame | Topology::SwSwDiff | Topology::HwSw => Platform::Sw,
            _ => Platform::Hw,
        }
    }

    /// True when both kernels share a node (no network protocol involved —
    /// these points are excluded from the UDP-speedup figure).
    pub fn same_node(&self) -> bool {
        matches!(self, Topology::SwSwSame | Topology::HwHwSame)
    }

    /// Label used in figures.
    pub fn label(&self) -> &'static str {
        match self {
            Topology::SwSwSame => "SW-SW (same)",
            Topology::SwSwDiff => "SW-SW (diff)",
            Topology::SwHw => "SW-HW",
            Topology::HwSw => "HW-SW",
            Topology::HwHwSame => "HW-HW (same)",
            Topology::HwHwDiff => "HW-HW (diff)",
        }
    }
}

impl std::fmt::Display for Topology {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_topologies() {
        assert_eq!(Topology::ALL.len(), 6);
    }

    #[test]
    fn platforms() {
        assert_eq!(Topology::SwHw.sender(), Platform::Sw);
        assert_eq!(Topology::SwHw.receiver(), Platform::Hw);
        assert_eq!(Topology::HwSw.sender(), Platform::Hw);
        assert_eq!(Topology::HwHwDiff.receiver(), Platform::Hw);
    }

    #[test]
    fn same_node_classification() {
        assert!(Topology::SwSwSame.same_node());
        assert!(Topology::HwHwSame.same_node());
        assert!(!Topology::SwSwDiff.same_node());
        assert!(!Topology::SwHw.same_node());
    }
}
