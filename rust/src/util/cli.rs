//! Tiny command-line argument parser (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and a
//! generated usage string. Each binary declares its options up front so
//! `--help` is accurate.

use std::collections::BTreeMap;

/// Declarative option specification for usage text.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    specs: Vec<OptSpec>,
    program: String,
}

impl Args {
    /// Build a parser with declared options; parse `std::env::args`.
    pub fn parse(specs: Vec<OptSpec>) -> Args {
        let argv: Vec<String> = std::env::args().collect();
        Self::parse_from(specs, &argv)
    }

    /// Parse from an explicit argv (used by tests).
    pub fn parse_from(specs: Vec<OptSpec>, argv: &[String]) -> Args {
        let mut a = Args {
            specs,
            program: argv.first().cloned().unwrap_or_default(),
            ..Default::default()
        };
        let mut i = 1;
        while i < argv.len() {
            let arg = &argv[i];
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else {
                    let is_flag = a
                        .specs
                        .iter()
                        .find(|s| s.name == stripped)
                        .map(|s| s.is_flag)
                        .unwrap_or_else(|| {
                            // Unknown option: treat as flag if next token
                            // looks like another option or is absent.
                            argv.get(i + 1).map(|n| n.starts_with("--")).unwrap_or(true)
                        });
                    if is_flag {
                        a.flags.push(stripped.to_string());
                    } else if let Some(v) = argv.get(i + 1) {
                        a.opts.insert(stripped.to_string(), v.clone());
                        i += 1;
                    } else {
                        a.flags.push(stripped.to_string());
                    }
                }
            } else {
                a.positional.push(arg.clone());
            }
            i += 1;
        }
        a
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated list of usize, e.g. `--grids 256,512,1024`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            Some(v) => v.split(',').filter_map(|t| t.trim().parse().ok()).collect(),
            None => default.to_vec(),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Render usage text from the declared specs.
    pub fn usage(&self, about: &str) -> String {
        let mut s = format!("{about}\n\nUSAGE: {} [OPTIONS]\n\nOPTIONS:\n", self.program);
        for spec in &self.specs {
            let arg = if spec.is_flag {
                format!("--{}", spec.name)
            } else {
                format!("--{} <value>", spec.name)
            };
            let def = spec
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  {arg:<28} {}{def}\n", spec.help));
        }
        s
    }

    pub fn wants_help(&self) -> bool {
        self.flag("help") || self.flag("h")
    }
}

/// Shorthand for declaring an option that takes a value.
pub fn opt(name: &'static str, help: &'static str, default: &'static str) -> OptSpec {
    OptSpec { name, help, default: Some(default), is_flag: false }
}

/// Shorthand for declaring a boolean flag.
pub fn flag(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec { name, help, default: None, is_flag: true }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        std::iter::once("prog".to_string())
            .chain(s.iter().map(|x| x.to_string()))
            .collect()
    }

    #[test]
    fn parses_key_value_both_styles() {
        let a = Args::parse_from(
            vec![opt("size", "", "1"), opt("n", "", "1")],
            &argv(&["--size", "42", "--n=7"]),
        );
        assert_eq!(a.get_usize("size", 0), 42);
        assert_eq!(a.get_usize("n", 0), 7);
    }

    #[test]
    fn parses_flags_and_positional() {
        let a = Args::parse_from(
            vec![flag("verbose", ""), opt("k", "", "1")],
            &argv(&["--verbose", "pos1", "--k", "3", "pos2"]),
        );
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string(), "pos2".to_string()]);
        assert_eq!(a.get_usize("k", 0), 3);
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse_from(
            vec![opt("grids", "", "")],
            &argv(&["--grids", "256, 512,1024"]),
        );
        assert_eq!(a.get_usize_list("grids", &[]), vec![256, 512, 1024]);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse_from(vec![opt("x", "", "5")], &argv(&[]));
        assert_eq!(a.get_usize("x", 5), 5);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn usage_mentions_options() {
        let a = Args::parse_from(vec![opt("size", "payload bytes", "64"), flag("hw", "use hw")], &argv(&[]));
        let u = a.usage("test tool");
        assert!(u.contains("--size <value>"));
        assert!(u.contains("--hw"));
        assert!(u.contains("[default: 64]"));
    }
}
