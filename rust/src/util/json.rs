//! Minimal JSON parser + emitter.
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`) and for machine-readable benchmark reports. Only
//! the subset of JSON those producers emit is required, but the parser is a
//! complete RFC-8259 recursive-descent implementation minus `\u` surrogate
//! pairs outside the BMP.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(Error::Json(format!("trailing data at byte {}", p.i)));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access helper: `v.get("name")`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize to a compact JSON string.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::Json(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            )))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(Error::Json(format!("bad literal at byte {}", self.i)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Json(format!("unexpected {other:?} at byte {}", self.i))),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self
                .peek()
                .ok_or_else(|| Error::Json("unterminated string".into()))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self
                        .peek()
                        .ok_or_else(|| Error::Json("bad escape".into()))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(Error::Json("short \\u escape".into()));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| Error::Json("bad \\u escape".into()))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::Json("bad \\u escape".into()))?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(Error::Json("bad escape char".into())),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // Multibyte UTF-8: find the full sequence.
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.i - 1;
                    let end = (start + len).min(self.b.len());
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| Error::Json("invalid utf-8".into()))?;
                    s.push_str(chunk);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Json(format!("bad number '{text}'")))
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(Error::Json(format!("bad array sep {other:?}"))),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(Error::Json(format!("bad object sep {other:?}"))),
            }
        }
    }
}

/// Convenience builder: `obj([("a", Json::Num(1.0))])`.
pub fn obj<const N: usize>(fields: [(&str, Json); N]) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience: JSON string value.
pub fn s(v: impl Into<String>) -> Json {
    Json::Str(v.into())
}

/// Convenience: JSON number value.
pub fn n(v: f64) -> Json {
    Json::Num(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\n\t\"\\ A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\ A"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ✓"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"n":null,"t":true}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string_compact(), "3");
        assert_eq!(Json::Num(3.25).to_string_compact(), "3.25");
    }
}
