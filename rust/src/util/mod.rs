//! Small self-contained utilities.
//!
//! The build environment is fully offline with only the `xla` crate's
//! dependency closure vendored, so the pieces a crates.io project would pull
//! in (`rand`, `serde_json`, `clap`, `criterion`, `proptest`) are implemented
//! here as minimal, tested equivalents.

pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;

/// Format a nanosecond quantity with an adaptive unit, e.g. `12.3 µs`.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Format a bytes/second rate with an adaptive unit, e.g. `1.21 GB/s`.
pub fn fmt_rate(bytes_per_sec: f64) -> String {
    if bytes_per_sec < 1e3 {
        format!("{bytes_per_sec:.1} B/s")
    } else if bytes_per_sec < 1e6 {
        format!("{:.2} KB/s", bytes_per_sec / 1e3)
    } else if bytes_per_sec < 1e9 {
        format!("{:.2} MB/s", bytes_per_sec / 1e6)
    } else {
        format!("{:.2} GB/s", bytes_per_sec / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1_500.0), "1.50 µs");
        assert_eq!(fmt_ns(2_000_000.0), "2.00 ms");
        assert_eq!(fmt_ns(3_000_000_000.0), "3.000 s");
    }

    #[test]
    fn fmt_rate_units() {
        assert_eq!(fmt_rate(10.0), "10.0 B/s");
        assert_eq!(fmt_rate(1_500.0), "1.50 KB/s");
        assert_eq!(fmt_rate(2_000_000.0), "2.00 MB/s");
        assert_eq!(fmt_rate(1_210_000_000.0), "1.21 GB/s");
    }
}
