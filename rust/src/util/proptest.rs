//! Mini property-testing framework (no `proptest` crate offline).
//!
//! `check(name, cases, |rng| ...)` runs a closure over many seeded RNG
//! streams; on failure it reports the failing seed so the case can be
//! replayed deterministically with `check_seed`. Shrinking is by seed replay
//! rather than structural shrinking — adequate for the codec/allocator/router
//! invariants we assert.

use super::rng::Rng;

/// Outcome of a single property case.
pub type CaseResult = Result<(), String>;

/// Run `cases` random cases of property `f`. Panics with the failing seed on
/// the first violated case.
pub fn check(name: &str, cases: u64, f: impl Fn(&mut Rng) -> CaseResult) {
    let base = env_seed().unwrap_or(0x5480a1_u64);
    for i in 0..cases {
        let seed = base.wrapping_add(i).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property '{name}' failed on case {i} (seed {seed:#x}): {msg}\n\
                 replay with: SHOAL_PROP_SEED={base} and case index {i}"
            );
        }
    }
}

/// Replay one specific seed (for debugging a reported failure).
pub fn check_seed(name: &str, seed: u64, f: impl Fn(&mut Rng) -> CaseResult) {
    let mut rng = Rng::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("property '{name}' failed on seed {seed:#x}: {msg}");
    }
}

fn env_seed() -> Option<u64> {
    std::env::var("SHOAL_PROP_SEED").ok()?.parse().ok()
}

/// Assert helper producing `CaseResult`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// Equality assert helper producing `CaseResult`.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 100, |rng| {
            let a = rng.next_u32() as u64;
            let b = rng.next_u32() as u64;
            prop_assert!(a + b == b + a, "addition must commute");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports_seed() {
        check("always-fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn check_seed_replays() {
        check_seed("replay", 0xdead_beef, |rng| {
            let _ = rng.next_u64();
            Ok(())
        });
    }
}
