//! Deterministic pseudo-random number generation.
//!
//! `SplitMix64` seeds `XorShift128Plus`; both are tiny, fast, and good enough
//! for workload generation and property-based tests (we need reproducibility,
//! not cryptographic strength).

/// SplitMix64 — used to expand a single `u64` seed into stream state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// XorShift128+ — the main workhorse RNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s0: u64,
    s1: u64,
}

impl Rng {
    /// Create a generator from a seed; any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s0 = sm.next_u64();
        let mut s1 = sm.next_u64();
        if s0 == 0 && s1 == 0 {
            s1 = 1; // xorshift state must be non-zero
        }
        Self { s0, s1 }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s0;
        let y = self.s1;
        self.s0 = y;
        x ^= x << 23;
        self.s1 = x ^ y ^ (x >> 17) ^ (y >> 26);
        self.s1.wrapping_add(y)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift reduction; slight
    /// modulo bias is acceptable for test-data generation.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform float in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fill a byte slice with random data.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let w = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&w[..rem.len()]);
        }
    }

    /// A fresh random byte vector of length `n`.
    pub fn bytes(&mut self, n: usize) -> Vec<u8> {
        let mut v = vec![0u8; n];
        self.fill_bytes(&mut v);
        v
    }

    /// Pick a random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn range_inclusive_bounds_hit() {
        let mut r = Rng::new(9);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            lo_seen |= v == 3;
            hi_seen |= v == 5;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(11);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = Rng::new(5);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        // Probability all 5 tail bytes are zero is ~2^-40.
        assert!(buf[8..].iter().any(|&b| b != 0));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn rough_uniformity() {
        let mut r = Rng::new(99);
        let mut buckets = [0usize; 10];
        for _ in 0..100_000 {
            buckets[r.below(10) as usize] += 1;
        }
        for &b in &buckets {
            assert!((8_000..12_000).contains(&b), "bucket {b}");
        }
    }
}
