//! Latency/throughput statistics: online summaries and percentile estimation.
//!
//! The microbenchmarks report *median* latency (paper Figs. 4–5) and mean
//! throughput (Fig. 6); `Summary` keeps raw samples (bounded) so exact
//! percentiles are available, and `Histogram` provides log-bucketed
//! aggregation for long-running counters.

/// Collects samples and produces exact order statistics.
///
/// Stores up to `cap` raw samples; pushes beyond that reservoir-sample so the
/// percentile estimates remain unbiased for very long runs.
#[derive(Clone, Debug)]
pub struct Summary {
    samples: Vec<f64>,
    cap: usize,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    rng_state: u64,
}

impl Default for Summary {
    fn default() -> Self {
        Self::with_capacity(1 << 16)
    }
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(cap: usize) -> Self {
        Self {
            samples: Vec::new(),
            cap,
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            rng_state: 0x853c_49e6_748f_ea9b,
        }
    }

    fn next_rand(&mut self) -> u64 {
        // xorshift64* — private stream for reservoir sampling.
        let mut x = self.rng_state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.rng_state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    pub fn push(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        if self.samples.len() < self.cap {
            self.samples.push(v);
        } else {
            let j = self.next_rand() % self.count;
            if (j as usize) < self.cap {
                self.samples[j as usize] = v;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Exact percentile over retained samples (q in [0,1]), linear
    /// interpolation between closest ranks.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        // total_cmp: a stray NaN sample (e.g. a zero-duration timing
        // artifact divided out) must not panic the whole bench report.
        s.sort_by(f64::total_cmp);
        let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            let frac = pos - lo as f64;
            s[lo] * (1.0 - frac) + s[hi] * frac
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(0.5)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }

    /// Sample standard deviation of retained samples.
    pub fn stddev(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.samples.iter().sum::<f64>() / self.samples.len() as f64;
        let var = self.samples.iter().map(|v| (v - m) * (v - m)).sum::<f64>()
            / (self.samples.len() - 1) as f64;
        var.sqrt()
    }
}

/// Log2-bucketed histogram for cheap hot-path recording (e.g. per-packet
/// sizes or cycle counts in the GAScore simulator).
#[derive(Clone, Debug)]
pub struct Histogram {
    buckets: [u64; 64],
    count: u64,
    sum: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self { buckets: [0; 64], count: 0, sum: 0 }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        let b = 64 - v.leading_zeros() as usize; // 0 -> bucket 0
        self.buckets[b.min(63)] += 1;
        self.count += 1;
        self.sum += v;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Upper bound of the bucket containing the q-quantile.
    pub fn quantile_upper_bound(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return if i == 0 { 0 } else { (1u64 << i) - 1 };
            }
        }
        u64::MAX
    }

    pub fn merge(&mut self, other: &Histogram) {
        for i in 0..64 {
            self.buckets[i] += other.buckets[i];
        }
        self.count += other.count;
        self.sum += other.sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic_stats() {
        let mut s = Summary::new();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(v);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.median() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_percentile_interpolates() {
        let mut s = Summary::new();
        for v in [0.0, 10.0] {
            s.push(v);
        }
        assert!((s.percentile(0.5) - 5.0).abs() < 1e-12);
        assert!((s.percentile(0.25) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_is_nan() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.median().is_nan());
    }

    /// Regression: percentile() used `partial_cmp().unwrap()`, which
    /// panicked the moment any sample was NaN. With `total_cmp` the sort
    /// is total — NaN sorts above +inf — and finite quantiles survive.
    #[test]
    fn percentile_survives_nan_and_inf_samples() {
        let mut s = Summary::new();
        for v in [3.0, f64::NAN, 1.0, f64::INFINITY, 2.0, f64::NEG_INFINITY] {
            s.push(v);
        }
        // Must not panic; the extremes land at the ends of the total order.
        assert_eq!(s.percentile(0.0), f64::NEG_INFINITY);
        assert!(s.percentile(1.0).is_nan(), "NaN sorts last under total_cmp");
        // The median of [-inf, 1, 2, 3, +inf, NaN] interpolates 2 and 3.
        assert!((s.median() - 2.5).abs() < 1e-12);
        let p40 = s.percentile(0.4);
        assert!(p40.is_finite(), "interior percentile stays finite, got {p40}");
    }

    #[test]
    fn reservoir_keeps_count_exact() {
        let mut s = Summary::with_capacity(100);
        for i in 0..10_000 {
            s.push(i as f64);
        }
        assert_eq!(s.count(), 10_000);
        assert_eq!(s.samples.len(), 100);
        // Median of 0..10000 is ~5000; the reservoir estimate should be in
        // the right neighbourhood.
        let m = s.median();
        assert!((2_000.0..8_000.0).contains(&m), "median {m}");
    }

    #[test]
    fn summary_stddev() {
        let mut s = Summary::new();
        for v in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(v);
        }
        assert!((s.stddev() - 2.138).abs() < 0.01);
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::new();
        for v in 0..1024u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 1024);
        let q50 = h.quantile_upper_bound(0.5);
        assert!(q50 >= 511 && q50 <= 1023, "q50={q50}");
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(1);
        b.record(100);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!((a.mean() - 50.5).abs() < 1e-9);
    }
}
