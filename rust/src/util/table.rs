//! ASCII table rendering for benchmark reports.
//!
//! The bench harness prints the same rows/series the paper's tables and
//! figures report; this module renders them aligned for terminals and as CSV
//! for plotting.

/// A simple column-aligned text table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>) -> Self {
        Self { title: title.into(), header: Vec::new(), rows: Vec::new() }
    }

    pub fn header<S: Into<String>>(mut self, cols: impl IntoIterator<Item = S>) -> Self {
        self.header = cols.into_iter().map(Into::into).collect();
        self
    }

    pub fn row<S: Into<String>>(&mut self, cols: impl IntoIterator<Item = S>) {
        self.rows.push(cols.into_iter().map(Into::into).collect());
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    fn widths(&self) -> Vec<usize> {
        let ncols = self
            .rows
            .iter()
            .map(|r| r.len())
            .chain(std::iter::once(self.header.len()))
            .max()
            .unwrap_or(0);
        let mut w = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = w[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// Render as an aligned ASCII table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cols: &[String], w: &[usize]| -> String {
            let mut line = String::new();
            for (i, width) in w.iter().enumerate() {
                let cell = cols.get(i).map(String::as_str).unwrap_or("");
                if i > 0 {
                    line.push_str("  ");
                }
                let pad = width - cell.chars().count();
                // Right-align numeric-looking cells, left-align text.
                let numeric = cell
                    .chars()
                    .next()
                    .map(|c| c.is_ascii_digit() || c == '-' || c == '+')
                    .unwrap_or(false);
                if numeric {
                    line.push_str(&" ".repeat(pad));
                    line.push_str(cell);
                } else {
                    line.push_str(cell);
                    line.push_str(&" ".repeat(pad));
                }
            }
            line.trim_end().to_string()
        };
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header, &w));
            out.push('\n');
            out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len().saturating_sub(1))));
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row, &w));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (RFC-4180-ish quoting).
    pub fn to_csv(&self) -> String {
        let quote = |c: &str| -> String {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = String::new();
        if !self.header.is_empty() {
            out.push_str(
                &self.header.iter().map(|c| quote(c)).collect::<Vec<_>>().join(","),
            );
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| quote(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo").header(["name", "value"]);
        t.row(["aa", "1"]);
        t.row(["b", "22"]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("name"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn csv_quotes_commas() {
        let mut t = Table::new("").header(["a"]);
        t.row(["x,y"]);
        t.row(["q\"z"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    fn empty_table() {
        let t = Table::new("t");
        assert!(t.is_empty());
        assert_eq!(t.to_csv(), "");
    }
}
