//! Steady-state allocation accounting for the zero-copy send datapath.
//!
//! The PR's claim is concrete: once pools and tables are warm, a medium AM
//! send performs **at most 2 heap allocations** on the issuing thread — the
//! wire buffer the borrowed-slice encoder fills (on the router path) or the
//! stream delivery buffer (on the intra-node fast path), plus the channel's
//! amortized block allocation. The owned-`AmMessage` baseline paid five
//! (args vec + payload vec + encode buffer + per-chunk copies).
//!
//! Counting is thread-local: the global allocator increments a counter only
//! while the *measuring* thread has switched it on, so the router/handler
//! threads (and the test harness's other tests) never pollute the count.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use shoal::config::{ClusterBuilder, Platform};
use shoal::prelude::*;

thread_local! {
    static COUNTING: Cell<bool> = const { Cell::new(false) };
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY: delegates every operation to `System`; the bookkeeping only
// touches const-initialized (allocation-free) thread-locals.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        // `try_with` tolerates TLS teardown; a dead TLS slot just skips the
        // count.
        let _ = COUNTING.try_with(|on| {
            if on.get() {
                let _ = ALLOCS.try_with(|n| n.set(n.get() + 1));
            }
        });
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Run `f` with allocation counting on; returns how many allocations the
/// current thread performed inside it.
fn count_allocs(f: impl FnOnce()) -> u64 {
    ALLOCS.with(|n| n.set(0));
    COUNTING.with(|on| on.set(true));
    f();
    COUNTING.with(|on| on.set(false));
    ALLOCS.with(|n| n.get())
}

const WARMUP: usize = 64;
const MEASURED: u64 = 128;

/// Drive `MEASURED` medium sends (after `WARMUP` unmeasured ones) through a
/// two-kernel cluster and return the total allocations attributed to the
/// `am_medium` calls themselves (each send is waited before the next, so
/// slab slots and token-map capacity are recycled — steady state).
fn measured_medium_allocs(fastpath: bool) -> u64 {
    let mut b = ClusterBuilder::new();
    let n = b.node("n0", Platform::Sw);
    b.kernel(n);
    b.kernel(n);
    b.default_segment(1 << 16);
    b.local_fastpath(fastpath);
    let spec = b.build().unwrap();
    let cluster = ShoalCluster::launch(&spec).unwrap();
    let (tx, rx) = std::sync::mpsc::channel::<u64>();

    cluster.run_kernel(1, |k| {
        // Drain everything the sender emits (WARMUP + MEASURED + sentinel).
        for _ in 0..WARMUP as u64 + MEASURED + 1 {
            let m = k.recv_medium().unwrap();
            if m.args.first() == Some(&u64::MAX) {
                break;
            }
        }
    });
    cluster.run_kernel(0, move |mut k| {
        let payload = [0xA5u8; 256];
        for _ in 0..WARMUP {
            let h = k.am_medium(1, handlers::NOP, &[], &payload).unwrap();
            k.wait(h).unwrap();
        }
        let mut total = 0u64;
        for _ in 0..MEASURED {
            let mut handle = None;
            total += count_allocs(|| {
                handle = Some(k.am_medium(1, handlers::NOP, &[], &payload).unwrap());
            });
            k.wait(handle.unwrap()).unwrap();
        }
        let h = k.am_medium(1, handlers::NOP, &[u64::MAX], &[]).unwrap();
        k.wait(h).unwrap();
        tx.send(total).unwrap();
    });

    let total = rx
        .recv_timeout(std::time::Duration::from_secs(120))
        .expect("sender finished");
    cluster.join().unwrap();
    total
}

/// The wire (router) datapath: WireBuilder encode into a pooled buffer plus
/// the channel hand-off must average ≤2 allocations per send.
#[test]
fn medium_send_wire_path_steady_state_allocs() {
    let total = measured_medium_allocs(false);
    assert!(total >= MEASURED, "counting is broken: {total} allocs for {MEASURED} sends");
    assert!(
        total <= 2 * MEASURED,
        "wire-path medium send not zero-copy: {total} allocs over {MEASURED} sends (> 2/send)"
    );
}

/// The intra-node fast path: the payload's stream-delivery buffer plus the
/// channel hand-off must also average ≤2 allocations per send.
#[test]
fn medium_send_fast_path_steady_state_allocs() {
    let total = measured_medium_allocs(true);
    assert!(total >= MEASURED, "counting is broken: {total} allocs for {MEASURED} sends");
    assert!(
        total <= 2 * MEASURED,
        "fast-path medium send not zero-copy: {total} allocs over {MEASURED} sends (> 2/send)"
    );
}

/// The borrowed-slice encoder itself is allocation-free into a warm buffer
/// (the buffer's capacity is the only allocation, paid once).
#[test]
fn wire_builder_encode_reuses_capacity() {
    use shoal::am::wire::{WireBuilder, WireDesc};
    use shoal::am::{AmFlags, AmType};
    let args = [1u64, 2, 3];
    let payload = [0x5Au8; 512];
    let wb = WireBuilder {
        am_type: AmType::Long,
        flags: AmFlags::new().with(AmFlags::FIFO),
        src: 1,
        dst: 2,
        handler: handlers::NOP,
        token: 9,
        args: &args,
        desc: WireDesc::Long { dst_addr: 1024 },
    };
    let mut buf = Vec::new();
    wb.encode_slice(&payload, &mut buf).unwrap(); // warms the capacity
    let n = count_allocs(|| {
        for _ in 0..64 {
            buf.clear();
            wb.encode_slice(&payload, &mut buf).unwrap();
        }
    });
    assert_eq!(n, 0, "encode into a warm buffer must not allocate");
}
