//! Remote atomics battery: exactness under concurrency on every datapath
//! (intra-node fast path, forced wire path, TCP, lossy reliable UDP), CAS
//! linearizability, and the typed one-sided `Rma` tier end-to-end.

use shoal::config::{ChunkPolicy, ClusterBuilder, ClusterSpec, Platform, TransportKind};
use shoal::prelude::*;

/// Handles in flight per kernel before a `wait_all` fence.
const WINDOW: usize = 16;

/// Serializes the env-writing lossy-UDP test against anything else in this
/// binary that might read env (concurrent `setenv`/`getenv` is UB on glibc).
static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Every kernel fires `per_kernel` tracked FAA(+1)s at kernel 0's word 0;
/// afterwards the word must equal kernels × per_kernel exactly — a lost or
/// double-applied atomic shows up as an off-by-n, a failed one fails its
/// handle.
fn concurrent_faa(cluster: &ShoalCluster, kernels: u16, per_kernel: usize, opts: OpOptions) {
    let (tx, rx) = std::sync::mpsc::channel();
    for kid in 0..kernels {
        let tx = tx.clone();
        cluster.run_kernel(kid, move |mut k| {
            if kid == 0 {
                k.mem().write(0, &[0u8; 8]).unwrap();
            }
            k.barrier().unwrap();
            let mut inflight: Vec<AmHandle> = Vec::new();
            for _ in 0..per_kernel {
                let h = k
                    .rma()
                    .faa(GlobalAddress::new(0, 0), AtomicOp::FaaAdd, 1, opts)
                    .unwrap();
                inflight.push(h.am);
                if inflight.len() == WINDOW {
                    k.wait_all(&inflight).unwrap();
                    inflight.clear();
                }
            }
            k.wait_all(&inflight).unwrap();
            k.barrier().unwrap();
            if kid == 0 {
                let word = k.mem().read(0, 8).unwrap();
                let sum = u64::from_le_bytes(word.try_into().unwrap());
                assert_eq!(
                    sum,
                    kernels as u64 * per_kernel as u64,
                    "concurrent FAA lost or duplicated updates"
                );
            }
            tx.send(()).unwrap();
        });
    }
    drop(tx);
    for _ in 0..kernels {
        rx.recv_timeout(std::time::Duration::from_secs(120)).expect("kernel finished");
    }
}

#[test]
fn concurrent_faa_exact_on_fast_path() {
    let spec = ClusterSpec::single_node("faa", 4);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    concurrent_faa(&cluster, 4, 500, OpOptions::default());
    cluster.join().unwrap();
}

#[test]
fn concurrent_faa_exact_on_forced_wire_path() {
    // Same node, but Locality::Wire pushes every atomic through codec +
    // router + AM engine — the datapath a remote kernel would take.
    let spec = ClusterSpec::single_node("faw", 4);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    concurrent_faa(&cluster, 4, 200, OpOptions::default().force_wire());
    cluster.join().unwrap();
}

#[test]
fn concurrent_faa_exact_over_tcp() {
    let mut b = ClusterBuilder::new();
    b.transport(TransportKind::Tcp);
    let n0 = b.node_at("a", Platform::Sw, "127.0.0.1:0");
    let n1 = b.node_at("b", Platform::Sw, "127.0.0.1:0");
    b.kernel(n0);
    b.kernel(n0);
    b.kernel(n1);
    b.kernel(n1);
    let spec = b.build().unwrap();
    let cluster = ShoalCluster::launch(&spec).unwrap();
    concurrent_faa(&cluster, 4, 150, OpOptions::default());
    cluster.join().unwrap();
}

#[test]
fn concurrent_faa_exact_over_lossy_reliable_udp() {
    // 8% injected datagram loss on every ARQ endpoint: the sliding-window
    // retransmit layer must deliver every atomic exactly once — the sum
    // check catches both losses and retransmit-induced double application.
    let _env = ENV_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    std::env::set_var("SHOAL_UDP_DROP", "0.08");
    let mut b = ClusterBuilder::new();
    b.transport(TransportKind::Udp);
    b.udp_window(16).udp_retries(8);
    let n0 = b.node_at("a", Platform::Sw, "127.0.0.1:0");
    let n1 = b.node_at("b", Platform::Sw, "127.0.0.1:0");
    b.kernel(n0);
    b.kernel(n1);
    b.kernel(n1);
    let spec = b.build().unwrap();
    let cluster = ShoalCluster::launch(&spec).unwrap();
    std::env::remove_var("SHOAL_UDP_DROP");
    concurrent_faa(&cluster, 3, 60, OpOptions::default());
    cluster.join().unwrap();
}

/// CAS linearizability on one hot word: competing kernels claim counter
/// values with read-then-CAS loops. Linearizability means every successful
/// CAS claims a *distinct* value, and the claimed set is exactly
/// `0..kernels*per_kernel` with the word left at the count.
#[test]
fn cas_increments_linearize_on_a_hot_word() {
    const KERNELS: u16 = 3;
    const PER: usize = 40;
    let spec = ClusterSpec::single_node("cas", KERNELS);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    let (tx, rx) = std::sync::mpsc::channel::<Vec<u64>>();
    for kid in 0..KERNELS {
        let tx = tx.clone();
        cluster.run_kernel(kid, move |mut k| {
            if kid == 0 {
                k.mem().write(0, &[0u8; 8]).unwrap();
            }
            k.barrier().unwrap();
            let addr = GlobalAddress::new(0, 0);
            let mut claimed = Vec::with_capacity(PER);
            while claimed.len() < PER {
                // FAA(+0) is an atomic read of the word.
                let h = k.rma().faa(addr, AtomicOp::FaaAdd, 0, OpOptions::default()).unwrap();
                let cur = k.wait_fetch(h.am).unwrap();
                let h = k.rma().cas(addr, cur, cur + 1, OpOptions::default()).unwrap();
                let seen = k.wait_fetch(h.am).unwrap();
                if seen == cur {
                    claimed.push(cur); // the CAS took effect at value `cur`
                }
            }
            k.barrier().unwrap();
            tx.send(claimed).unwrap();
        });
    }
    drop(tx);
    let mut all: Vec<u64> = Vec::new();
    for _ in 0..KERNELS {
        all.extend(rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap());
    }
    cluster.join().unwrap();
    all.sort_unstable();
    let expect: Vec<u64> = (0..KERNELS as u64 * PER as u64).collect();
    assert_eq!(all, expect, "CAS claims must be distinct and gap-free");
}

/// The `Rma` tier end-to-end: put / get / put_from / swap round trips, the
/// typed fetch values, and an f64 accumulate — all against real kernels.
#[test]
fn rma_tier_moves_data_and_fetches_old_values() {
    let spec = ClusterSpec::single_node("rma", 2);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(0, |mut k| {
        let dst = GlobalAddress::new(1, 0);
        // put then get back.
        let h = k.rma().put(dst, &5u64.to_le_bytes(), OpOptions::default()).unwrap();
        k.wait(h).unwrap();
        let h = k.rma().get(dst, 256, 8, OpOptions::default()).unwrap();
        k.wait(h).unwrap();
        assert_eq!(k.mem().read(256, 8).unwrap(), 5u64.to_le_bytes());

        // swap observes the put value; a FAA(+0) read observes the swap.
        let h = k.rma().swap(dst, 42, OpOptions::default()).unwrap();
        assert_eq!(k.rma().wait_fetch(h).unwrap(), 5);
        let h = k.rma().faa(dst, AtomicOp::FaaAdd, 0, OpOptions::default()).unwrap();
        assert_eq!(k.rma().wait_fetch(h).unwrap(), 42);

        // put_from: segment-to-segment from our own partition.
        k.mem().write(512, b"from-mem").unwrap();
        let h = k.rma().put_from(GlobalAddress::new(1, 64), 512, 8, OpOptions::default()).unwrap();
        k.wait(h).unwrap();

        // f64 accumulate: Sum lane-wise into the peer's partition.
        let h = k
            .rma()
            .put(GlobalAddress::new(1, 128), &shoal::collectives::encode_f64s(&[1.5, 2.5]), OpOptions::default())
            .unwrap();
        k.wait(h).unwrap();
        let h = k
            .rma()
            .accumulate(
                GlobalAddress::new(1, 128),
                ReduceOp::Sum,
                Lane::F64,
                &shoal::collectives::encode_f64s(&[0.25, 0.75]),
                OpOptions::default(),
            )
            .unwrap();
        k.wait(h).unwrap();
        k.barrier().unwrap();
    });
    cluster.run_kernel(1, |mut k| {
        k.barrier().unwrap();
        assert_eq!(k.mem().read(64, 8).unwrap(), b"from-mem");
        let acc = shoal::collectives::decode_f64s(&k.mem().read(128, 16).unwrap()).unwrap();
        assert_eq!(acc, vec![1.75, 3.25]);
    });
    cluster.join().unwrap();
}

/// `OpOptions` contracts: fire-and-forget is rejected where a reply is the
/// point (gets, fetch atomics), `Chunk::Single` overrides a chunking
/// cluster policy with a pre-send error, and a fetched value can be
/// extracted exactly once.
#[test]
fn op_options_and_fetch_contracts() {
    let mut b = ClusterBuilder::new();
    let n = b.node("n", Platform::Sw);
    b.kernel(n);
    b.kernel(n);
    b.default_segment(256 << 10);
    b.chunk_policy(ChunkPolicy::Chunked);
    let spec = b.build().unwrap();
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(0, |mut k| {
        let dst = GlobalAddress::new(1, 0);
        // Async get / async fetch atomic: rejected before anything is sent.
        let err = k.rma().get(dst, 0, 8, OpOptions::fire_and_forget()).unwrap_err();
        assert!(matches!(err, shoal::Error::BadDescriptor(_)), "{err}");
        let err = k
            .rma()
            .faa(dst, AtomicOp::FaaAdd, 1, OpOptions::fire_and_forget())
            .unwrap_err();
        assert!(matches!(err, shoal::Error::BadDescriptor(_)), "{err}");
        // CAS through the faa entry point is a usage error.
        let err = k.rma().faa(dst, AtomicOp::Cas, 1, OpOptions::default()).unwrap_err();
        assert!(matches!(err, shoal::Error::BadDescriptor(_)), "{err}");

        // The cluster chunks 40 KiB; Chunk::Single demands one AM instead.
        let big = vec![0xABu8; 40 << 10];
        let h = k.rma().put(dst, &big, OpOptions::default()).unwrap();
        assert!(h.messages > 1, "cluster policy must chunk: {}", h.messages);
        k.wait(h).unwrap();
        let err = k
            .rma()
            .put(dst, &big, OpOptions::default().single_message())
            .unwrap_err();
        assert!(matches!(err, shoal::Error::AmTooLarge { .. }), "{err}");

        // Fetch results are single-consumption: the second extraction is a
        // typed error, not a stale value.
        let h = k.rma().faa(dst, AtomicOp::FaaAdd, 3, OpOptions::default()).unwrap();
        let _ = k.wait_fetch(h.am).unwrap();
        let err = k.wait_fetch(h.am).unwrap_err();
        assert!(matches!(err, shoal::Error::OperationFailed(_)), "{err}");

        // Locality::Wire on the put still lands the data (datapath choice
        // is invisible to the memory contract).
        let h = k
            .rma()
            .put(GlobalAddress::new(1, 512), &[9u8; 16], OpOptions::default().force_wire())
            .unwrap();
        k.wait(h).unwrap();
        k.barrier().unwrap();
    });
    cluster.run_kernel(1, |mut k| {
        k.barrier().unwrap();
        assert_eq!(k.mem().read(512, 16).unwrap(), vec![9u8; 16]);
    });
    cluster.join().unwrap();
}

/// The in-process GUPS app (random Rma FAAs, windowed handles) is exact —
/// the same body `shoal serve --app gups` runs across real processes.
#[test]
fn gups_app_is_exact_in_process() {
    let r = shoal::apps::gups::run(&shoal::apps::gups::GupsConfig {
        kernels: 4,
        updates: 400,
        table_words: 128,
    })
    .unwrap();
    assert_eq!(r.total_updates, 1600);
    assert!(r.updates_per_sec > 0.0);
}
