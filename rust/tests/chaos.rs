//! Chaos battery: SIGKILL one node of a live three-node cluster and assert
//! the survivors fail **exactly what the dead node owes** — promptly, with
//! errors naming the peer — while traffic to the healthy node keeps
//! flowing and shutdown completes. `cfg(unix)` because the kill is a real
//! SIGKILL (no shutdown handshake, no FIN: the peer just goes silent).

#![cfg(unix)]

use std::io::{BufRead, BufReader, Write};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use shoal::config::parse::parse_cluster;
use shoal::prelude::*;
use shoal::shoal_node::cluster::ShoalCluster;

/// Guard serializing port allocation + binding across parallel tests (same
/// idiom as `multiprocess.rs`).
static PORT_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

const HEARTBEAT_MS: u64 = 100;
const SUSPECT_MS: u64 = 400;
const DEAD_MS: u64 = 1500;
/// The issue's bound: a survivor must observe the death within three
/// `dead_after` windows of the kill.
const DETECT_BUDGET: Duration = Duration::from_millis(3 * DEAD_MS);

fn free_ports3() -> (u16, u16, u16) {
    let a = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let b = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let c = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    (
        a.local_addr().unwrap().port(),
        b.local_addr().unwrap().port(),
        c.local_addr().unwrap().port(),
    )
}

/// Three nodes, one kernel each: driver (node 0, this process) plus two
/// spawned servers. Failure detection on; UDP runs with the ARQ layer
/// (raw UDP has no heartbeat path).
fn cluster_file(transport: &str, p0: u16, p1: u16, p2: u16) -> String {
    let udp = if transport == "udp" { "udp_window = 8\n" } else { "" };
    format!(
        r#"
transport = "{transport}"
heartbeat_interval = {HEARTBEAT_MS}
suspect_after = {SUSPECT_MS}
dead_after = {DEAD_MS}
{udp}
[[node]]
name = "driver"
platform = "sw"
address = "127.0.0.1:{p0}"

[[node]]
name = "server1"
platform = "sw"
address = "127.0.0.1:{p1}"

[[node]]
name = "server2"
platform = "sw"
address = "127.0.0.1:{p2}"

[[kernel]]
node = "driver"

[[kernel]]
node = "server1"

[[kernel]]
node = "server2"
"#
    )
}

fn write_cluster(dir_tag: &str, text: &str) -> (std::path::PathBuf, std::path::PathBuf) {
    let dir = std::env::temp_dir().join(dir_tag);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cluster.toml");
    let mut f = std::fs::File::create(&path).unwrap();
    f.write_all(text.as_bytes()).unwrap();
    (dir, path)
}

fn spawn_server(path: &std::path::Path, node: u16, app: &str) -> Child {
    Command::new(env!("CARGO_BIN_EXE_shoal"))
        .args([
            "serve",
            "--cluster",
            path.to_str().unwrap(),
            "--node",
            &node.to_string(),
            "--app",
            app,
            "--max-msgs",
            "0",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn shoal serve")
}

/// Block until the server prints its "node up" line — its transport is
/// bound, so the driver's detector can't falsely suspect a peer that is
/// merely still exec'ing.
fn wait_ready(child: &mut Child) {
    let stdout = child.stdout.take().expect("server stdout piped");
    let mut line = String::new();
    BufReader::new(stdout).read_line(&mut line).expect("server readiness line");
    assert!(line.contains("up"), "unexpected server banner: {line:?}");
}

fn reap(mut child: Child) {
    let _ = child.kill();
    let _ = child.wait();
}

/// SIGKILL a peer mid-stream: the probes in flight toward it fail with
/// `Error::PeerDead` naming node 2 within the detection budget, later
/// sends fail at issue, traffic to the surviving server still completes,
/// no handle is stranded, and shutdown returns.
fn kill_mid_stream(transport: &'static str) {
    let _guard = PORT_LOCK.lock().unwrap();
    let (p0, p1, p2) = free_ports3();
    let text = cluster_file(transport, p0, p1, p2);
    let spec = parse_cluster(&text).unwrap();
    let (dir, path) = write_cluster(&format!("shoal-chaos-{transport}-{p0}"), &text);

    // Driver first: its ingress binds inside `launch_node`, so the servers'
    // detectors find a live listener from their very first heartbeat. The
    // driver's own first heartbeats toward the still-exec'ing servers are
    // covered by the never-heard startup grace: connect-ladder exhaustion
    // only hard-kills a peer that has produced liveness evidence before,
    // so a slow-starting server answers its first connect well inside the
    // dead_after silence budget.
    let cluster = ShoalCluster::launch_node(&spec, 0).unwrap();
    let mut s1 = spawn_server(&path, 1, "echo");
    let mut s2 = spawn_server(&path, 2, "echo");
    wait_ready(&mut s1);
    wait_ready(&mut s2);

    let (warm_tx, warm_rx) = mpsc::channel();
    let (killed_tx, killed_rx) = mpsc::channel();
    let (done_tx, done_rx) = mpsc::channel();
    cluster.run_kernel(0, move |mut k| {
        // Warm-up: both servers echo, so every connection/window is live.
        for target in [1u16, 2] {
            let h = k.am_medium(target, handlers::NOP, &[7], b"warmup").unwrap();
            k.wait(h).unwrap();
            let echo = k.recv_medium().unwrap();
            assert_eq!(echo.src, target, "warmup echo from the wrong kernel");
        }
        warm_tx.send(()).unwrap();
        killed_rx.recv().unwrap(); // server 2 is now SIGKILLed

        // Probe the dead peer until its handles fail structurally. Early
        // probes may fail with transport-flavoured reasons (a batch that
        // died with the first broken flush); the detector must upgrade
        // that to `PeerDead` naming node 2 within the budget. Every wait
        // returns — a stranded handle would hang here and trip the outer
        // timeout.
        let start = Instant::now();
        let mut named_dead = None;
        while start.elapsed() < DETECT_BUDGET + Duration::from_secs(5) {
            let h = k.am_medium(2, handlers::NOP, &[1], b"probe").unwrap();
            match k.wait(h) {
                Err(shoal::Error::PeerDead { node, .. }) => {
                    named_dead = Some((node, start.elapsed()));
                    break;
                }
                Ok(_) | Err(_) => std::thread::sleep(Duration::from_millis(25)),
            }
        }
        let (node, elapsed) = named_dead.expect("no PeerDead error before the outer cap");
        assert_eq!(node, 2, "death must name the killed node");
        assert!(
            elapsed <= DETECT_BUDGET,
            "detection took {elapsed:?}, budget {DETECT_BUDGET:?} over {transport}"
        );

        // Fail-at-issue: the next send toward the fenced peer dies without
        // touching the transport.
        let h = k.am_medium(2, handlers::NOP, &[2], b"post-mortem").unwrap();
        match k.wait(h) {
            Err(shoal::Error::PeerDead { node: 2, .. }) => {}
            other => panic!("expected fenced send to fail PeerDead(2), got {other:?}"),
        }

        // The surviving server is untouched by the fence.
        let h = k.am_medium(1, handlers::NOP, &[9], b"survivor").unwrap();
        k.wait(h).unwrap();
        let echo = k.recv_medium().unwrap();
        assert_eq!(echo.src, 1, "survivor echo");
        done_tx.send(()).unwrap();
    });

    warm_rx
        .recv_timeout(Duration::from_secs(60))
        .expect("warmup over both servers");
    let kill_at = Instant::now();
    reap(s2);
    killed_tx.send(()).unwrap();
    done_rx
        .recv_timeout(Duration::from_secs(60))
        .unwrap_or_else(|_| panic!("driver kernel hung after the kill over {transport}"));
    assert!(
        kill_at.elapsed() <= DETECT_BUDGET + Duration::from_secs(30),
        "post-kill phase exceeded every budget"
    );

    // The node-level view agrees: node 2 dead, handles fenced, epoch
    // bumped — and only node 2 (`server1` stays Alive).
    let health = cluster.peer_health(0).expect("detector runs with heartbeats on");
    assert!(health.is_dead(2));
    assert!(!health.is_dead(1));
    assert!(health.membership_epoch() >= 1);
    let stats = cluster.router_stats(0).unwrap();
    assert!(stats.peers_dead.load(Ordering::Relaxed) >= 1);
    assert!(stats.fenced_handles.load(Ordering::Relaxed) >= 1);

    cluster.join().unwrap();
    reap(s1);
    std::fs::remove_dir_all(&dir).ok();
}

/// SIGKILL a peer mid-all-reduce: the in-flight collective fails naming
/// node 2 instead of hanging, and new collectives fail at issue.
fn kill_mid_all_reduce(transport: &'static str) {
    let _guard = PORT_LOCK.lock().unwrap();
    let (p0, p1, p2) = free_ports3();
    let text = cluster_file(transport, p0, p1, p2);
    let spec = parse_cluster(&text).unwrap();
    let (dir, path) = write_cluster(&format!("shoal-chaos-ar-{transport}-{p0}"), &text);

    // Same ordering as `kill_mid_stream`: bind the driver's ingress before
    // the servers start heartbeating at it. The remote kernels' hellos
    // queue on kernel 0's stream until `run_kernel` starts consuming.
    let cluster = ShoalCluster::launch_node(&spec, 0).unwrap();
    let mut s1 = spawn_server(&path, 1, "allreduce");
    let mut s2 = spawn_server(&path, 2, "allreduce");
    wait_ready(&mut s1);
    wait_ready(&mut s2);

    let (armed_tx, armed_rx) = mpsc::channel();
    let (killed_tx, killed_rx) = mpsc::channel();
    let (done_tx, done_rx) = mpsc::channel();
    cluster.run_kernel(0, move |mut k| {
        // Both remote kernels repeat hello until released (the allreduce
        // app's handshake). Release kernel 1 only: kernel 2 dies instead
        // of ever contributing, so the collective is genuinely in flight
        // and incompletable when the kill lands.
        let mut seen = std::collections::HashSet::new();
        while seen.len() < 2 {
            seen.insert(k.recv_medium().unwrap().src);
        }
        k.am_medium_async(1, handlers::NOP, &[], b"go").unwrap();
        let ch = k.all_reduce_u64(ReduceOp::Sum, &[k.id() as u64]).unwrap();
        armed_tx.send(()).unwrap();
        killed_rx.recv().unwrap(); // server 2 is now SIGKILLed

        let start = Instant::now();
        match k.collective_wait_u64(ch) {
            Err(shoal::Error::PeerDead { node, .. }) => {
                assert_eq!(node, 2, "collective abort must name the killed node");
            }
            other => panic!("expected the all-reduce to fail PeerDead(2), got {other:?}"),
        }
        assert!(
            start.elapsed() <= DETECT_BUDGET,
            "collective abort took {:?}, budget {DETECT_BUDGET:?} over {transport}",
            start.elapsed()
        );

        // A dead member poisons future collectives at issue — no new
        // operation may strand on the fenced peer.
        match k.all_reduce_u64(ReduceOp::Sum, &[1]) {
            Err(shoal::Error::PeerDead { node: 2, .. }) => {}
            Ok(_) => panic!("new collective must fail at issue with a dead member"),
            Err(other) => panic!("expected PeerDead(2) at issue, got {other}"),
        }
        done_tx.send(()).unwrap();
    });

    armed_rx
        .recv_timeout(Duration::from_secs(60))
        .expect("collective armed with kernel 2 unreleased");
    reap(s2);
    killed_tx.send(()).unwrap();
    done_rx
        .recv_timeout(Duration::from_secs(60))
        .unwrap_or_else(|_| panic!("all-reduce hung after the kill over {transport}"));

    cluster.join().unwrap();
    // Server 1 was released into the same doomed collective; its own
    // detector aborts it too, so the process exits (on its own or via the
    // reaper) — either way it must not wedge this test.
    reap(s1);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sigkill_mid_stream_tcp() {
    kill_mid_stream("tcp");
}

#[test]
fn sigkill_mid_stream_udp() {
    kill_mid_stream("udp");
}

#[test]
fn sigkill_mid_all_reduce_tcp() {
    kill_mid_all_reduce("tcp");
}

#[test]
fn sigkill_mid_all_reduce_udp() {
    kill_mid_all_reduce("udp");
}
