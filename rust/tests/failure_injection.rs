//! Failure injection: the runtime must degrade gracefully, never hang or
//! corrupt state.

use shoal::config::{ClusterBuilder, ClusterSpec, Platform, TransportKind};
use shoal::galapagos::packet::Packet;
use shoal::galapagos::router::RouterMsg;
use shoal::prelude::*;

/// Sending to an unknown kernel id is an immediate API error.
#[test]
fn unknown_destination_rejected_at_api() {
    let spec = ClusterSpec::single_node("n", 1);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(0, |mut k| {
        let err = k.am_short(42, handlers::NOP, &[]).unwrap_err();
        assert!(matches!(err, shoal::Error::UnknownKernel(42)));
    });
    cluster.join().unwrap();
}

/// Out-of-bounds remote writes are dropped at the destination (error logged,
/// no reply suppressed — the sender still gets its ack for async=false? No:
/// the engine fails before reply creation, so the sender must NOT count on
/// the ack; state stays intact).
#[test]
fn out_of_bounds_long_put_does_not_corrupt() {
    let mut b = ClusterBuilder::new();
    let n = b.node("n", Platform::Sw);
    let k0 = b.kernel(n);
    let k1 = b.kernel_with_segment(n, 1024);
    let spec = b.build().unwrap();
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(k0, move |mut k| {
        // Write far beyond k1's 1 KiB segment: rejected at the destination.
        let _ = k.am_long_async(k1, handlers::NOP, &[], &[1; 64], 1 << 20).unwrap();
        // A valid put afterwards still works.
        let h = k.am_long(k1, handlers::NOP, &[], &[2; 64], 0).unwrap();
        k.wait(h).unwrap();
        k.barrier().unwrap();
    });
    cluster.run_kernel(k1, move |mut k| {
        k.barrier().unwrap();
        assert_eq!(k.mem().read(0, 64).unwrap(), vec![2; 64]);
    });
    cluster.join().unwrap();
}

/// Malformed packets injected straight into a router are dropped without
/// taking the node down.
#[test]
fn malformed_network_packet_is_dropped() {
    let spec = ClusterSpec::single_node("n", 2);
    let cluster = ShoalCluster::launch(&spec).unwrap();

    // Inject garbage as if it came from the network.
    // (Reach the router through a kernel handle's channel.)
    cluster.run_kernel(0, |mut k| {
        k.barrier().unwrap();
        // Normal traffic still works after the garbage.
        let h = k.am_medium(1, handlers::NOP, &[], b"after-garbage").unwrap();
        k.wait(h).unwrap();
    });
    cluster.run_kernel(1, |mut k| {
        k.barrier().unwrap();
        let m = k.recv_medium().unwrap();
        assert_eq!(m.payload, b"after-garbage");
    });
    cluster.join().unwrap();
}

/// A panicking kernel function is reported by join() and does not wedge the
/// other kernels (they complete their own work first).
#[test]
fn kernel_panic_is_reported() {
    let spec = ClusterSpec::single_node("n", 2);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(0, |_k| {
        panic!("injected failure");
    });
    cluster.run_kernel(1, |k| {
        // Does its own work without depending on kernel 0.
        k.mem().write(0, &[1]).unwrap();
    });
    let err = cluster.join().unwrap_err();
    assert!(err.to_string().contains("kernel 0 panicked"), "{err}");
}

/// Barrier participants that never arrive produce a timeout, not a hang.
#[test]
fn barrier_timeout_not_deadlock() {
    let spec = ClusterSpec::single_node("n", 2);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    // Kernel 0 is the barrier master and never calls barrier(): kernel 1's
    // ENTER is recorded but no RELEASE ever comes.
    cluster.run_kernel(1, |mut k| {
        k.timeout = std::time::Duration::from_millis(300);
        let err = k.barrier().unwrap_err();
        assert!(matches!(err, shoal::Error::Timeout(_)), "{err}");
    });
    cluster.run_kernel(0, |_k| {
        std::thread::sleep(std::time::Duration::from_millis(50));
    });
    cluster.join().unwrap();
}

/// Oversized UDP datagrams from a hardware node are refused (the FPGA UDP
/// core cannot fragment) while small ones flow.
#[test]
fn hw_udp_fragmentation_refused() {
    // UDP clusters create ARQ endpoints, which read loss-injection env
    // vars: serialize against the env-writing tests below.
    let _env = ENV_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let mut b = ClusterBuilder::new();
    b.transport(TransportKind::Udp);
    let n0 = b.node_at("fpga", Platform::Hw, "127.0.0.1:0");
    let n1 = b.node_at("cpu", Platform::Sw, "127.0.0.1:0");
    let k0 = b.kernel(n0);
    let k1 = b.kernel(n1);
    let spec = b.build().unwrap();
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(k0, move |mut k| {
        // Small payload crosses fine.
        let h = k.am_medium(k1, handlers::NOP, &[], &[1; 256]).unwrap();
        k.wait(h).unwrap();
        // A 2 KiB payload exceeds the MTU: the hardware UDP core drops it.
        // The router logs the egress failure; the send itself returns Ok
        // because the API handed the packet to the middleware (asynchronous
        // failure, as on the real FPGA where the core silently drops —
        // §IV-B1 "These packets may have been dropped by the core").
        let _ = k.am_medium_async(k1, handlers::NOP, &[], &[2; 2048]).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(100));
        // Traffic continues to flow afterwards.
        let h = k.am_medium(k1, handlers::NOP, &[], &[3; 128]).unwrap();
        k.wait(h).unwrap();
    });
    cluster.run_kernel(k1, move |mut k| {
        let a = k.recv_medium().unwrap();
        assert_eq!(a.payload, vec![1; 256]);
        let b = k.recv_medium().unwrap();
        assert_eq!(b.payload, vec![3; 128], "2 KiB datagram must have been dropped");
    });
    cluster.join().unwrap();
}

/// A collective with a dropped contribution fails with
/// `Error::OperationFailed` naming the straggler kernel — never a hang.
#[test]
fn dropped_collective_contribution_names_straggler() {
    let spec = ClusterSpec::single_node("n", 2);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    // Kernel 1 never joins the all-reduce: kernel 0 (the tree root) must
    // time out quickly and attribute the failure to kernel 1.
    cluster.run_kernel(0, |mut k| {
        k.timeout = std::time::Duration::from_millis(300);
        let ch = k.all_reduce_u64(ReduceOp::Sum, &[1]).unwrap();
        let err = k.collective_wait_u64(ch).unwrap_err();
        assert!(matches!(err, shoal::Error::OperationFailed(_)), "{err}");
        let msg = err.to_string();
        assert!(msg.contains("kernel 1"), "straggler kernel 1 not named: {msg}");
        assert!(msg.contains("never contributed"), "ledger view missing: {msg}");
        assert!(msg.contains("all-reduce"), "collective kind not named: {msg}");
        // The handle is failed, not leaked: a later wait agrees instead of
        // timing out again.
        let err2 = k.wait(ch.am).unwrap_err();
        assert!(matches!(err2, shoal::Error::OperationFailed(_)), "{err2}");
    });
    cluster.run_kernel(1, |_k| {
        // Deliberately absent from the collective.
        std::thread::sleep(std::time::Duration::from_millis(50));
    });
    cluster.join().unwrap();
}

/// A non-root kernel whose parent never fans the result down also fails
/// with a diagnosis (no result from the parent) instead of hanging.
#[test]
fn missing_parent_result_is_diagnosed() {
    let spec = ClusterSpec::single_node("n", 2);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    // Kernel 1 contributes; root kernel 0 never participates, so the DOWN
    // never comes.
    cluster.run_kernel(1, |mut k| {
        k.timeout = std::time::Duration::from_millis(300);
        let ch = k.all_reduce_u64(ReduceOp::Sum, &[7]).unwrap();
        let err = k.collective_wait_u64(ch).unwrap_err();
        assert!(matches!(err, shoal::Error::OperationFailed(_)), "{err}");
        let msg = err.to_string();
        assert!(msg.contains("parent kernel 0"), "parent not named: {msg}");
    });
    cluster.run_kernel(0, |_k| {
        std::thread::sleep(std::time::Duration::from_millis(50));
    });
    cluster.join().unwrap();
}

/// Decoding hostile wire bytes through the packet layer never panics.
#[test]
fn hostile_wire_bytes() {
    let mut rng = shoal::util::rng::Rng::new(0xBAD);
    for _ in 0..10_000 {
        let len = rng.below(64) as usize;
        let buf = rng.bytes(len);
        let _ = Packet::from_wire(&buf);
    }
    // RouterMsg variants carrying short garbage are constructible and
    // droppable without issue.
    let _ = RouterMsg::FromNetwork(Packet::new(0, 0, vec![0xFF; 3]).unwrap());
}

/// Serializes the tests that read or write process environment variables
/// (the jacobi fault hook writes; ARQ endpoint creation reads
/// `SHOAL_UDP_DROP`) — concurrent `setenv`/`getenv` is UB on glibc.
static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Reliable-UDP retry exhaustion must fail the EXACT operation that sent
/// the lost datagram with a typed error — not strand its handle until the
/// API timeout. Node 1's address is a black hole (bound socket nobody
/// reads, so nothing is ever acknowledged).
#[test]
fn exhausted_udp_retries_fail_the_owning_handle() {
    let _env = ENV_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let black_hole = std::net::UdpSocket::bind("127.0.0.1:0").unwrap();
    let hole_addr = black_hole.local_addr().unwrap().to_string();

    let mut b = ClusterBuilder::new();
    b.transport(TransportKind::Udp);
    b.default_segment(1 << 16);
    // Fail fast: one retransmission, ~10 ms RTO.
    b.udp_window(4).udp_retries(1).udp_ack_interval_ms(1);
    let n0 = b.node_at("driver", Platform::Sw, "127.0.0.1:0");
    let n1 = b.node_at("ghost", Platform::Sw, &hole_addr);
    let k0 = b.kernel(n0);
    let k1 = b.kernel(n1);
    let spec = b.build().unwrap();

    // Host only node 0; node 1 "exists" at the black hole.
    let cluster = ShoalCluster::launch_node(&spec, n0).unwrap();
    let (tx, rx) = std::sync::mpsc::channel();
    cluster.run_kernel(k0, move |mut k| {
        let h = k.am_long(k1, handlers::NOP, &[], &[9u8; 64], 0).unwrap();
        let t0 = std::time::Instant::now();
        let err = k.wait(h).unwrap_err();
        tx.send((err, t0.elapsed())).unwrap();
    });
    let (err, took) = rx
        .recv_timeout(std::time::Duration::from_secs(30))
        .expect("handle must fail, not hang");
    cluster.join().unwrap();
    assert!(matches!(err, shoal::Error::OperationFailed(_)), "{err}");
    let msg = err.to_string();
    assert!(msg.contains("retries exhausted"), "cause not named: {msg}");
    assert!(
        took < std::time::Duration::from_secs(10),
        "failed via retry exhaustion, not a wait timeout ({took:?})"
    );
}

/// A worker kernel failure must surface from `jacobi::run` as a typed
/// error naming the worker — the historical `panic!` took down the whole
/// process. Uses the `SHOAL_JACOBI_FAULT_WORKER` injection hook.
#[test]
fn jacobi_worker_failure_propagates_as_typed_error() {
    let _env = ENV_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    std::env::set_var("SHOAL_JACOBI_FAULT_WORKER", "1");
    let cfg = shoal::apps::jacobi::JacobiConfig {
        n: 18,
        iters: 4,
        workers: 2,
        ..shoal::apps::jacobi::JacobiConfig::default()
    };
    let err = shoal::apps::jacobi::run(&cfg).unwrap_err();
    std::env::remove_var("SHOAL_JACOBI_FAULT_WORKER");
    assert!(matches!(err, shoal::Error::OperationFailed(_)), "{err}");
    let msg = err.to_string();
    assert!(msg.contains("worker 1"), "worker not named: {msg}");
    assert!(msg.contains("injected worker fault"), "cause not chained: {msg}");

    // With the hook cleared the same configuration runs clean.
    let report = shoal::apps::jacobi::run(&cfg).unwrap();
    assert_eq!(report.iters_done, 4);
}
