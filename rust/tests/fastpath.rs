//! Intra-node one-sided fast-path semantics.
//!
//! Local puts/gets between same-node software kernels bypass codec + router
//! and resolve their handle at issue time. These tests pin down the
//! observable contract: immediate completion, data placement, handler
//! notification (payload-free), slow-path fallbacks, and the compatibility
//! of both completion models (`wait`/`test` and the `wait_replies` shim).

use shoal::config::{ChunkPolicy, ClusterBuilder, ClusterSpec, Platform};
use shoal::prelude::*;

/// A local Long put lands in the destination partition and its handle is
/// complete at issue time — `test` succeeds without any waiting, which is
/// only possible if the operation never entered the router round trip.
#[test]
#[allow(deprecated)] // deliberately exercises the wait_replies shim alongside handles
fn long_put_completes_at_issue_time() {
    let spec = ClusterSpec::single_node("n", 2);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(0, |mut k| {
        let h = k.am_long(1, handlers::NOP, &[], &[7; 128], 64).unwrap();
        assert_eq!(h.messages, 1);
        assert!(k.test(h).unwrap(), "local put must be complete at issue time");
        // The shim model works too (separate op, consumed via wait_replies).
        let _ = k.am_long(1, handlers::NOP, &[], &[8; 16], 512).unwrap();
        k.wait_replies(1).unwrap();
        k.barrier().unwrap();
    });
    cluster.run_kernel(1, |mut k| {
        k.barrier().unwrap();
        assert_eq!(k.mem().read(64, 128).unwrap(), vec![7; 128]);
        assert_eq!(k.mem().read(512, 16).unwrap(), vec![8; 16]);
    });
    cluster.join().unwrap();
}

/// Local Long and Medium gets complete at issue time: the data is copied
/// segment-to-segment (or segment-to-stream) with no round trip.
#[test]
fn gets_complete_at_issue_time() {
    let spec = ClusterSpec::single_node("n", 2);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(1, |mut k| {
        k.mem().write(0, &[9; 64]).unwrap();
        k.mem().write(64, b"stream-me").unwrap();
        k.barrier().unwrap();
        k.barrier().unwrap();
    });
    cluster.run_kernel(0, |mut k| {
        k.barrier().unwrap(); // peer's partition seeded
        let h = k.am_long_get(1, handlers::NOP, 0, 64, 128).unwrap();
        assert!(k.test(h).unwrap(), "local long get must be complete at issue time");
        assert_eq!(k.mem().read(128, 64).unwrap(), vec![9; 64]);

        let h = k.am_medium_get(1, handlers::NOP, 64, 9).unwrap();
        assert!(k.test(h).unwrap(), "local medium get must be complete at issue time");
        let m = k.recv_medium().unwrap();
        assert_eq!(m.payload, b"stream-me");
        assert_eq!(m.src, 1, "data reply is attributed to the responder");
        k.barrier().unwrap();
    });
    cluster.join().unwrap();
}

/// A get from this kernel's own partition (self-get) is a same-segment copy
/// — the aliasing case the segment-to-segment copy must handle.
#[test]
fn self_get_copies_within_one_segment() {
    let spec = ClusterSpec::single_node("n", 1);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(0, |mut k| {
        k.mem().write(0, &[3; 32]).unwrap();
        let h = k.am_long_get(0, handlers::NOP, 0, 32, 256).unwrap();
        assert!(k.test(h).unwrap());
        assert_eq!(k.mem().read(256, 32).unwrap(), vec![3; 32]);
    });
    cluster.join().unwrap();
}

/// Strided and vectored local puts scatter directly into the destination
/// partition.
#[test]
fn strided_and_vectored_local_puts() {
    let spec = ClusterSpec::single_node("n", 2);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(0, |mut k| {
        let data: Vec<u8> = (0..32).collect();
        let h = k.am_long_strided(1, handlers::NOP, &[], &data, 0, 16, 8).unwrap();
        assert!(k.test(h).unwrap());
        let h = k
            .am_long_vectored(1, handlers::NOP, &[], &[1, 2, 3, 4], &[(100, 2), (200, 2)])
            .unwrap();
        assert!(k.test(h).unwrap());
        k.barrier().unwrap();
    });
    cluster.run_kernel(1, |mut k| {
        k.barrier().unwrap();
        assert_eq!(k.mem().read(0, 8).unwrap(), (0..8).collect::<Vec<u8>>());
        assert_eq!(k.mem().read(16, 8).unwrap(), (8..16).collect::<Vec<u8>>());
        assert_eq!(k.mem().read(100, 2).unwrap(), vec![1, 2]);
        assert_eq!(k.mem().read(200, 2).unwrap(), vec![3, 4]);
    });
    cluster.join().unwrap();
}

/// A registered user handler still fires for a fast-path Long put — as a
/// payload-free notification on the destination's handler thread, strictly
/// after the data is visible in the partition.
#[test]
fn long_put_with_user_handler_notifies_payload_free() {
    let spec = ClusterSpec::single_node("n", 2);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster
        .register_handler(1, 20, |a| {
            // Notification contract: args intact, no payload, data already
            // in the partition at the address named by args[0].
            assert!(a.payload.is_empty(), "notification AM carries no payload");
            let data = a.segment.read(a.args[0], 4).unwrap();
            assert_eq!(data, vec![5; 4], "data must be visible before the handler runs");
            a.segment.write(a.args[1], &[1]).unwrap(); // handler-ran flag
        })
        .unwrap();
    cluster.run_kernel(0, |mut k| {
        let h = k.am_long(1, 20, &[64, 900], &[5; 4], 64).unwrap();
        assert!(k.test(h).unwrap(), "put itself completes at issue time");
        k.barrier().unwrap(); // notification precedes the barrier fan (FIFO per source)
    });
    cluster.run_kernel(1, |mut k| {
        k.barrier().unwrap();
        assert_eq!(k.mem().read(900, 1).unwrap(), vec![1], "user handler never fired");
    });
    cluster.join().unwrap();
}

/// A Medium put to a registered user handler takes the slow path: the
/// handler's contract includes the payload, so it must run on the handler
/// thread with the bytes in hand (the pre-fast-path behavior, unchanged).
#[test]
fn medium_put_with_user_handler_keeps_payload() {
    let spec = ClusterSpec::single_node("n", 2);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster
        .register_handler(1, 21, |a| {
            a.segment.write(a.args[0], a.payload).unwrap();
        })
        .unwrap();
    cluster.run_kernel(0, |mut k| {
        let h = k.am_medium(1, 21, &[300], &[4, 5, 6]).unwrap();
        k.wait(h).unwrap();
        k.barrier().unwrap();
    });
    cluster.run_kernel(1, |mut k| {
        let m = k.recv_medium().unwrap();
        assert_eq!(m.payload, vec![4, 5, 6]);
        k.barrier().unwrap();
        assert_eq!(k.mem().read(300, 3).unwrap(), vec![4, 5, 6]);
    });
    cluster.join().unwrap();
}

/// Size policy is unchanged by the fast path: an oversized Long put under
/// the default Reject policy errors locally too, and a chunked local put
/// still reports per-chunk `messages` for the shim bookkeeping.
#[test]
#[allow(deprecated)] // checks the shim counter is credited for local chunked puts
fn size_policies_apply_locally() {
    let spec = ClusterSpec::single_node("n", 2);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(0, |mut k| {
        let big = vec![0u8; 64 << 10];
        let err = k.am_long(1, handlers::NOP, &[], &big, 0).unwrap_err();
        assert!(matches!(err, shoal::Error::AmTooLarge { .. }), "{err}");
    });
    cluster.run_kernel(1, |_k| {});
    cluster.join().unwrap();

    let mut b = ClusterBuilder::new();
    let n = b.node("c", Platform::Sw);
    b.kernel(n);
    b.kernel(n);
    b.chunk_policy(ChunkPolicy::Chunked);
    b.default_segment(256 << 10);
    let spec = b.build().unwrap();
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(0, |mut k| {
        let big = vec![0xEEu8; 40 << 10];
        let h = k.am_long(1, handlers::NOP, &[], &big, 0).unwrap();
        assert!(h.messages > 1, "40 KB must still chunk-account: {}", h.messages);
        assert!(k.test(h).unwrap(), "local chunked put still completes at issue time");
        k.wait_replies(0).unwrap(); // shim counter already credited
        k.barrier().unwrap();
    });
    cluster.run_kernel(1, |mut k| {
        k.barrier().unwrap();
        assert_eq!(k.mem().read(0, 40 << 10).unwrap(), vec![0xEE; 40 << 10]);
    });
    cluster.join().unwrap();
}

/// `local_fastpath = false` forces the full router datapath: a local put is
/// NOT complete at issue time (the ack still has to round-trip), but lands
/// all the same.
#[test]
fn knob_disables_fast_path() {
    let mut b = ClusterBuilder::new();
    let n = b.node("n", Platform::Sw);
    b.kernel(n);
    b.kernel(n);
    b.default_segment(1 << 16);
    b.local_fastpath(false);
    let spec = b.build().unwrap();
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(0, |mut k| {
        let h = k.am_long(1, handlers::NOP, &[], &[6; 32], 0).unwrap();
        k.wait(h).unwrap();
        k.barrier().unwrap();
    });
    cluster.run_kernel(1, |mut k| {
        k.barrier().unwrap();
        assert_eq!(k.mem().read(0, 32).unwrap(), vec![6; 32]);
    });
    cluster.join().unwrap();
}

/// An out-of-bounds local put keeps the wire path's failure shape: the send
/// call succeeds and the failure is attributed to the operation's handle
/// (`Error::OperationFailed`), never silently corrupting anything.
#[test]
fn out_of_bounds_local_put_fails_the_handle() {
    let mut b = ClusterBuilder::new();
    let n = b.node("n", Platform::Sw);
    b.kernel(n);
    b.kernel_with_segment(n, 1024);
    let spec = b.build().unwrap();
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(0, |mut k| {
        let h = k.am_long(1, handlers::NOP, &[], &[1; 64], 1 << 20).unwrap();
        let err = k.wait(h).unwrap_err();
        assert!(matches!(err, shoal::Error::OperationFailed(_)), "{err}");
        // Async variant: dropped silently, like the engine.
        let _ = k.am_long_async(1, handlers::NOP, &[], &[1; 64], 1 << 20).unwrap();
        // A valid put afterwards still works.
        let h = k.am_long(1, handlers::NOP, &[], &[2; 64], 0).unwrap();
        k.wait(h).unwrap();
        k.barrier().unwrap();
    });
    cluster.run_kernel(1, |mut k| {
        k.barrier().unwrap();
        assert_eq!(k.mem().read(0, 64).unwrap(), vec![2; 64]);
    });
    cluster.join().unwrap();
}

/// Cross-node kernels never take the fast path: over a real transport the
/// put must still round-trip (completion is not immediate) and the wire
/// behavior is untouched.
#[test]
fn cross_node_keeps_the_wire_path() {
    let mut b = ClusterBuilder::new();
    b.transport(shoal::config::TransportKind::Tcp);
    b.default_segment(1 << 16);
    let n0 = b.node_at("a", Platform::Sw, "127.0.0.1:0");
    let n1 = b.node_at("b", Platform::Sw, "127.0.0.1:0");
    let k0 = b.kernel(n0);
    let k1 = b.kernel(n1);
    let spec = b.build().unwrap();
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(k0, move |mut k| {
        let h = k.am_long(k1, handlers::NOP, &[], &[4; 64], 0).unwrap();
        k.wait(h).unwrap(); // really waits on the remote ack
        k.barrier().unwrap();
    });
    cluster.run_kernel(k1, move |mut k| {
        k.barrier().unwrap();
        assert_eq!(k.mem().read(0, 64).unwrap(), vec![4; 64]);
    });
    cluster.join().unwrap();
}

/// `from_mem` variants go segment-to-segment locally.
#[test]
fn from_mem_put_copies_segment_to_segment() {
    let spec = ClusterSpec::single_node("n", 2);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(0, |mut k| {
        k.mem().write(32, &[0xAB; 48]).unwrap();
        let h = k.am_long_from_mem(1, handlers::NOP, &[], 32, 48, 4096).unwrap();
        assert!(k.test(h).unwrap());
        k.mem().write(128, b"mem-medium").unwrap();
        let h = k.am_medium_from_mem(1, handlers::NOP, &[], 128, 10).unwrap();
        assert!(k.test(h).unwrap());
        k.barrier().unwrap();
    });
    cluster.run_kernel(1, |mut k| {
        let m = k.recv_medium().unwrap();
        assert_eq!(m.payload, b"mem-medium");
        k.barrier().unwrap();
        assert_eq!(k.mem().read(4096, 48).unwrap(), vec![0xAB; 48]);
    });
    cluster.join().unwrap();
}
