//! Connection-scaling acceptance test for the readiness-polled ingress:
//! one 4-shard node must serve ≥128 *simultaneous* TCP peers with
//! O(shards) ingress threads — no thread per connection — while
//! preserving exactly-once, in-order delivery per peer and dispatching
//! each peer's flow to the shard owning its source node (the PR 7
//! invariant).

use std::collections::HashMap;
use std::io::Write;
use std::net::TcpStream;
use std::sync::mpsc;
use std::time::Duration;

use shoal::galapagos::packet::Packet;
use shoal::galapagos::router::{shard_of_node, RouterHandle, RouterMsg, RoutingTable};
use shoal::galapagos::transport::tcp::TcpIngress;

const PEERS: u16 = 128;
const SHARDS: usize = 4;
const FRAMES_PER_PEER: u8 = 32;

/// Length-prefixed wire frame carrying one packet from kernel `src`.
fn frame(src: u16, seq: u8) -> Vec<u8> {
    let pkt = Packet::new(0, src, vec![seq, 0xAB, 0xCD]).unwrap();
    let wire = pkt.to_wire();
    let mut out = Vec::with_capacity(4 + wire.len());
    out.extend_from_slice(&(wire.len() as u32).to_le_bytes());
    out.extend_from_slice(&wire);
    out
}

#[test]
fn polled_node_serves_128_peers_with_shard_count_threads() {
    // Kernel i lives on node i: source-peer ownership is then
    // `shard_of_node(src)` exactly as the sharded router computes it.
    let table = std::sync::Arc::new(RoutingTable::new(
        (0..=PEERS).map(|i| (i, i)),
    ));
    let (txs, rxs): (Vec<_>, Vec<_>) = (0..SHARDS).map(|_| mpsc::channel()).unzip();
    let handle = RouterHandle::new(0, table, txs);
    let mut ingress = TcpIngress::bind_polled("127.0.0.1:0", handle, SHARDS).unwrap();
    let addr = ingress.local_addr();

    // One poller thread per shard, independent of how many peers connect.
    assert_eq!(ingress.ingress_threads(), SHARDS);

    // All 128 streams open before any traffic flows: the node holds every
    // connection simultaneously.
    let mut streams: Vec<(u16, TcpStream)> = (1..=PEERS)
        .map(|peer| {
            let s = TcpStream::connect(addr).unwrap_or_else(|e| {
                panic!("peer {peer} failed to connect (of {PEERS}): {e}")
            });
            (peer, s)
        })
        .collect();

    // Per-shard drains, started before the writers so backpressure can't
    // wedge the pollers' dispatch.
    let drains: Vec<_> = rxs
        .into_iter()
        .enumerate()
        .map(|(shard, rx)| {
            std::thread::spawn(move || {
                // Nodes 1..=128 split evenly: 32 peers per shard.
                let expect = (PEERS as usize / SHARDS) * FRAMES_PER_PEER as usize;
                let mut got: Vec<(u16, u8)> = Vec::with_capacity(expect);
                while got.len() < expect {
                    match rx.recv_timeout(Duration::from_secs(30)) {
                        Ok(RouterMsg::FromNetwork(p)) => got.push((p.src, p.data[0])),
                        Ok(other) => panic!("shard {shard}: unexpected {other:?}"),
                        Err(e) => panic!(
                            "shard {shard}: stalled at {}/{expect} packets: {e}",
                            got.len()
                        ),
                    }
                }
                got
            })
        })
        .collect();

    // Interleave writes across all peers, splitting every frame into two
    // TCP writes so shards constantly juggle partial-frame decode state
    // across hundreds of streams.
    for seq in 0..FRAMES_PER_PEER {
        for (peer, stream) in &mut streams {
            let f = frame(*peer, seq);
            stream.write_all(&f[..3]).unwrap();
            stream.write_all(&f[3..]).unwrap();
        }
    }

    let mut per_peer: HashMap<u16, Vec<u8>> = HashMap::new();
    for (shard, d) in drains.into_iter().enumerate() {
        for (src, seq) in d.join().unwrap() {
            assert_eq!(
                shard_of_node(src, SHARDS),
                shard,
                "peer {src} dispatched to shard {shard}, not its owner"
            );
            per_peer.entry(src).or_default().push(seq);
        }
    }
    assert_eq!(per_peer.len(), PEERS as usize, "every peer must deliver");
    let want: Vec<u8> = (0..FRAMES_PER_PEER).collect();
    for (peer, seqs) in &per_peer {
        assert_eq!(seqs, &want, "peer {peer}: exactly-once in-order delivery broken");
    }

    // Steady state with 128 live connections: still O(shards) threads.
    assert_eq!(ingress.ingress_threads(), SHARDS);

    drop(streams);
    ingress.shutdown();
}
