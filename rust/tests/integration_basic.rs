//! End-to-end integration: the full Shoal API over in-process clusters.

use shoal::config::{ChunkPolicy, ClusterBuilder, ClusterSpec, Platform, TransportKind};
use shoal::prelude::*;

/// The shipped example cluster files parse, validate, and (for the local
/// one) actually launch.
#[test]
fn example_cluster_files_are_valid() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("examples/clusters");
    let het = shoal::config::parse::load_cluster(&dir.join("heterogeneous.toml")).unwrap();
    assert_eq!(het.nodes.len(), 3);
    assert_eq!(het.kernel_count(), 9);
    assert_eq!(het.transport, TransportKind::Tcp);
    assert!(het.node(1).unwrap().platform.is_hw());

    let p2p = shoal::config::parse::load_cluster(&dir.join("point_to_point.toml")).unwrap();
    assert_eq!(p2p.profile, shoal::config::ApiProfile::point_to_point());
    // The local file is launchable as-is.
    let cluster = ShoalCluster::launch(&p2p).unwrap();
    cluster.run_kernel(0, |mut k| {
        let h = k.am_medium(1, handlers::NOP, &[], b"hi").unwrap();
        k.wait(h).unwrap();
    });
    cluster.run_kernel(1, |k| {
        assert_eq!(k.recv_medium().unwrap().payload, b"hi");
    });
    cluster.run_kernel(2, |_| {});
    cluster.run_kernel(3, |_| {});
    cluster.join().unwrap();
}

/// Medium FIFO put between two kernels on one software node.
#[test]
fn medium_put_same_node() {
    let spec = ClusterSpec::single_node("n0", 2);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(0, |mut k| {
        let r = k.am_medium(1, handlers::NOP, &[1, 2], b"hello pgas").unwrap();
        assert_eq!(r.messages, 1);
        k.wait(r).unwrap();
    });
    cluster.run_kernel(1, |k| {
        let m = k.recv_medium().unwrap();
        assert_eq!(m.payload, b"hello pgas");
        assert_eq!(m.src, 0);
        assert_eq!(m.args, vec![1, 2]);
    });
    cluster.join().unwrap();
}

/// Long put writes the destination partition; destination observes it after
/// a barrier.
#[test]
fn long_put_and_barrier() {
    let spec = ClusterSpec::single_node("n0", 2);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(0, |mut k| {
        let h = k.am_long(1, handlers::NOP, &[], &[42u8; 64], 128).unwrap();
        k.wait(h).unwrap();
        k.barrier().unwrap();
    });
    cluster.run_kernel(1, |mut k| {
        k.barrier().unwrap();
        assert_eq!(k.mem().read(128, 64).unwrap(), vec![42u8; 64]);
    });
    cluster.join().unwrap();
}

/// Medium get and Long get across two software nodes over the local fabric.
#[test]
fn gets_across_nodes() {
    let mut b = ClusterBuilder::new();
    let n0 = b.node("a", Platform::Sw);
    let n1 = b.node("b", Platform::Sw);
    let k0 = b.kernel(n0);
    let k1 = b.kernel(n1);
    let spec = b.build().unwrap();
    let cluster = ShoalCluster::launch(&spec).unwrap();

    cluster.run_kernel(k1, |mut k| {
        k.mem().write(64, &[9, 8, 7, 6]).unwrap();
        k.barrier().unwrap(); // data ready
        k.barrier().unwrap(); // peer done
    });
    cluster.run_kernel(k0, move |mut k| {
        k.barrier().unwrap();
        // Medium get: payload arrives on the stream.
        let r = k.am_medium_get(k1, handlers::NOP, 64, 4).unwrap();
        assert_eq!(r.messages, 1);
        let m = k.recv_medium().unwrap();
        assert_eq!(m.payload, vec![9, 8, 7, 6]);
        k.wait(r).unwrap();

        // Long get: payload lands in our partition.
        let r = k.am_long_get(k1, handlers::NOP, 64, 4, 256).unwrap();
        k.wait(r).unwrap();
        assert_eq!(k.mem().read(256, 4).unwrap(), vec![9, 8, 7, 6]);
        k.barrier().unwrap();
    });
    cluster.join().unwrap();
}

/// Hardware node: kernels behind a GAScore, TCP loopback between nodes.
#[test]
fn sw_to_hw_over_tcp() {
    let mut b = ClusterBuilder::new();
    b.transport(TransportKind::Tcp);
    let n0 = b.node_at("cpu", Platform::Sw, "127.0.0.1:0");
    let n1 = b.node_at("fpga", Platform::Hw, "127.0.0.1:0");
    let k0 = b.kernel(n0);
    let k1 = b.kernel(n1);
    let spec = b.build().unwrap();
    let cluster = ShoalCluster::launch(&spec).unwrap();

    cluster.run_kernel(k0, move |mut k| {
        let put = k.am_long(k1, handlers::NOP, &[], &[5u8; 1024], 0).unwrap();
        k.wait(put).unwrap();
        let r = k.am_long_get(k1, handlers::NOP, 0, 1024, 0).unwrap();
        k.wait(r).unwrap();
        assert_eq!(k.mem().read(0, 1024).unwrap(), vec![5u8; 1024]);
        k.barrier().unwrap();
    });
    cluster.run_kernel(k1, |mut k| {
        k.barrier().unwrap();
        assert_eq!(k.mem().read(0, 1024).unwrap(), vec![5u8; 1024]);
    });

    // GAScore processed traffic for the HW node.
    cluster.join().unwrap();
}

/// Strided and vectored puts scatter correctly.
#[test]
fn strided_and_vectored() {
    let spec = ClusterSpec::single_node("n0", 2);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(0, |mut k| {
        let payload: Vec<u8> = (0..32).collect();
        let a = k.am_long_strided(1, handlers::NOP, &[], &payload, 0, 16, 8).unwrap();
        let b = k
            .am_long_vectored(1, handlers::NOP, &[], &[1, 2, 3, 4], &[(100, 2), (200, 2)])
            .unwrap();
        k.wait_all(&[a, b]).unwrap();
        k.barrier().unwrap();
    });
    cluster.run_kernel(1, |mut k| {
        k.barrier().unwrap();
        assert_eq!(k.mem().read(0, 8).unwrap(), (0..8).collect::<Vec<u8>>());
        assert_eq!(k.mem().read(16, 8).unwrap(), (8..16).collect::<Vec<u8>>());
        assert_eq!(k.mem().read(100, 2).unwrap(), vec![1, 2]);
        assert_eq!(k.mem().read(200, 2).unwrap(), vec![3, 4]);
    });
    cluster.join().unwrap();
}

/// The chunking extension moves payloads beyond one packet.
#[test]
fn chunked_long_put() {
    let mut b = ClusterBuilder::new();
    let n0 = b.node("n0", Platform::Sw);
    let k0 = b.kernel(n0);
    let k1 = b.kernel(n0);
    b.chunk_policy(ChunkPolicy::Chunked);
    let spec = b.build().unwrap();
    let cluster = ShoalCluster::launch(&spec).unwrap();

    let big: Vec<u8> = (0..40_000u32).map(|i| (i % 251) as u8).collect();
    let expect = big.clone();
    cluster.run_kernel(k0, move |mut k| {
        let r = k.am_long(k1, handlers::NOP, &[], &big, 0).unwrap();
        assert!(r.messages > 1, "40 KB must chunk: {}", r.messages);
        k.wait(r).unwrap();
        k.barrier().unwrap();
    });
    cluster.run_kernel(k1, move |mut k| {
        k.barrier().unwrap();
        assert_eq!(k.mem().read(0, 40_000).unwrap(), expect);
    });
    cluster.join().unwrap();
}

/// Default (paper) policy rejects oversized AMs — the §IV-C1 failure mode.
#[test]
fn reject_policy_errors_on_oversize() {
    let spec = ClusterSpec::single_node("n0", 2);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(0, |mut k| {
        let big = vec![0u8; 16 * 1024]; // 4096-wide f32 row
        let err = k.am_long(1, handlers::NOP, &[], &big, 0).unwrap_err();
        assert!(matches!(err, shoal::Error::AmTooLarge { .. }), "{err}");
    });
    cluster.run_kernel(1, |_k| {});
    cluster.join().unwrap();
}

/// Barrier synchronizes many kernels repeatedly (stress the epoch logic).
#[test]
fn barrier_stress() {
    let spec = ClusterSpec::single_node("n0", 8);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    let counter = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
    for kid in 0..8 {
        let c = std::sync::Arc::clone(&counter);
        cluster.run_kernel(kid, move |mut k| {
            for round in 0..20u64 {
                // Everyone observes the same count at each barrier.
                k.barrier().unwrap();
                let v = c.load(std::sync::atomic::Ordering::SeqCst);
                assert_eq!(v, round, "kernel {kid} saw {v} at round {round}");
                k.barrier().unwrap();
                if kid == 0 {
                    c.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                }
                k.barrier().unwrap();
            }
        });
    }
    cluster.join().unwrap();
}

/// User handlers run on the receiving handler thread (software kernels).
#[test]
fn user_handler_fires() {
    let spec = ClusterSpec::single_node("n0", 2);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    // Handler 20 doubles the first payload byte into the segment at args[0].
    cluster
        .register_handler(1, 20, |a| {
            a.segment.write(a.args[0], &[a.payload[0] * 2]).unwrap();
        })
        .unwrap();
    cluster.run_kernel(0, |mut k| {
        let h = k.am_medium(1, 20, &[500], &[21]).unwrap();
        k.wait(h).unwrap();
        k.barrier().unwrap();
    });
    cluster.run_kernel(1, |mut k| {
        let _ = k.recv_medium().unwrap();
        k.barrier().unwrap();
        assert_eq!(k.mem().read(500, 1).unwrap(), vec![42]);
    });
    cluster.join().unwrap();
}

/// API profile enforcement (paper §V-A modular future work).
#[test]
fn profile_blocks_disabled_classes() {
    let mut b = ClusterBuilder::new();
    let n0 = b.node("n0", Platform::Sw);
    b.kernel(n0);
    b.kernel(n0);
    b.profile(shoal::config::ApiProfile::point_to_point());
    let spec = b.build().unwrap();
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(0, |mut k| {
        // Medium works under the point-to-point profile…
        let h = k.am_medium(1, handlers::NOP, &[], b"ok").unwrap();
        k.wait(h).unwrap();
        // …but Long is disabled.
        let err = k.am_long(1, handlers::NOP, &[], &[0; 8], 0).unwrap_err();
        assert!(matches!(err, shoal::Error::ProfileViolation(_)));
        k.barrier().unwrap(); // barrier is enabled
    });
    cluster.run_kernel(1, |mut k| {
        let _ = k.recv_medium().unwrap();
        k.barrier().unwrap();
    });
    cluster.join().unwrap();
}

/// Handle-based completion end-to-end: overlapped Long gets resolved by
/// `wait_all`, out-of-order completion attribution via `test`, and
/// `wait_any` picking the first finished operation.
#[test]
fn handle_waits_complete_overlapped_gets() {
    let spec = ClusterSpec::single_node("h", 2);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(1, |mut k| {
        for i in 0..4u8 {
            k.mem().write(i as u64 * 256, &[i + 1; 256]).unwrap();
        }
        k.barrier().unwrap();
        k.barrier().unwrap(); // stay alive while kernel 0 gets
    });
    cluster.run_kernel(0, |mut k| {
        k.barrier().unwrap();
        // Four independent gets in flight at once, one fence.
        let handles: Vec<AmHandle> = (0..4u64)
            .map(|i| {
                k.am_long_get(1, handlers::NOP, i * 256, 256, i * 256).unwrap()
            })
            .collect();
        assert!(handles.iter().all(|h| h.messages == 1));
        k.wait_all(&handles).unwrap();
        for i in 0..4u8 {
            assert_eq!(k.mem().read(i as u64 * 256, 256).unwrap(), vec![i + 1; 256]);
        }
        // wait_any returns an index of a completed operation.
        let a = k.am_long_get(1, handlers::NOP, 0, 16, 0).unwrap();
        let b = k.am_long_get(1, handlers::NOP, 256, 16, 16).unwrap();
        let i = k.wait_any(&[a, b]).unwrap();
        assert!(i < 2);
        // Consume the other one too before the final barrier.
        let other = if i == 0 { b } else { a };
        k.wait(other).unwrap();
        k.barrier().unwrap();
    });
    cluster.join().unwrap();
}

/// A chunked put returns ONE handle covering all chunks; waiting it is
/// equivalent to the old "sum the receipts" collective bookkeeping.
#[test]
fn chunked_put_completes_under_one_handle() {
    let mut b = ClusterBuilder::new();
    let n0 = b.node("n0", Platform::Sw);
    b.kernel(n0);
    b.kernel(n0);
    b.default_segment(256 << 10);
    b.chunk_policy(ChunkPolicy::Chunked);
    let spec = b.build().unwrap();
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(0, |mut k| {
        let payload = vec![0xB7u8; 40 << 10];
        let h = k.am_long(1, handlers::NOP, &[], &payload, 0).unwrap();
        assert!(h.messages > 1, "40 KB must chunk: {}", h.messages);
        k.wait(h).unwrap();
        k.barrier().unwrap();
    });
    cluster.run_kernel(1, |mut k| {
        k.barrier().unwrap();
        assert_eq!(k.mem().read(0, 40 << 10).unwrap(), vec![0xB7; 40 << 10]);
    });
    cluster.join().unwrap();
}

/// Handle waits and the wait_replies shim coexist on one kernel as long as
/// each operation is consumed exactly once. Deliberately exercises the
/// deprecated counter shim to keep it honest until removal.
#[test]
#[allow(deprecated)]
fn handle_and_shim_waits_interleave() {
    let spec = ClusterSpec::single_node("m", 2);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(0, |mut k| {
        let a = k.am_long(1, handlers::NOP, &[], &[1; 64], 0).unwrap(); // handle-waited
        let _b = k.am_long(1, handlers::NOP, &[], &[2; 64], 64).unwrap(); // shim-waited
        k.wait(a).unwrap();
        k.wait_replies(1).unwrap();
        assert_eq!(k.pending_replies(), 0);
        k.barrier().unwrap();
    });
    cluster.run_kernel(1, |mut k| {
        k.barrier().unwrap();
        assert_eq!(k.mem().read(0, 64).unwrap(), vec![1; 64]);
        assert_eq!(k.mem().read(64, 64).unwrap(), vec![2; 64]);
    });
    cluster.join().unwrap();
}

/// Collectives over a heterogeneous cluster: software and (simulated)
/// hardware kernels join the same all-reduce through their respective
/// runtimes, and the GAScore's collective counters observe the traffic.
#[test]
fn all_reduce_across_sw_and_hw_nodes() {
    let mut b = ClusterBuilder::new();
    b.transport(TransportKind::Tcp);
    b.default_segment(1 << 12);
    let n0 = b.node_at("cpu", Platform::Sw, "127.0.0.1:0");
    let n1 = b.node_at("fpga", Platform::Hw, "127.0.0.1:0");
    let k0 = b.kernel(n0);
    let k1 = b.kernel(n1);
    let k2 = b.kernel(n1);
    let spec = b.build().unwrap();
    let cluster = ShoalCluster::launch(&spec).unwrap();

    let (tx, rx) = std::sync::mpsc::channel();
    for kid in [k0, k1, k2] {
        let tx = tx.clone();
        cluster.run_kernel(kid, move |mut k| {
            let ch = k.all_reduce_u64(ReduceOp::Max, &[kid as u64, 7]).unwrap();
            let got = k.collective_wait_u64(ch).unwrap();
            tx.send((kid, got)).unwrap();
        });
    }
    drop(tx);
    for _ in 0..3 {
        let (kid, got) = rx
            .recv_timeout(std::time::Duration::from_secs(30))
            .expect("all-reduce result");
        assert_eq!(got, vec![2, 7], "kernel {kid}");
    }
    let stats = cluster.gascore_stats(n1).expect("hw node has a GAScore");
    assert!(
        stats.collectives_in.load(std::sync::atomic::Ordering::Relaxed) > 0,
        "GAScore never saw a collective message"
    );
    cluster.join().unwrap();
}

/// Broadcast and reduce round out the collective set; the tree barrier
/// synchronizes like the counter barrier.
#[test]
fn bcast_reduce_and_tree_barrier() {
    use shoal::collectives::Lane;
    let spec = ClusterSpec::single_node("c", 4);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    let (tx, rx) = std::sync::mpsc::channel();
    for kid in 0..4u16 {
        let tx = tx.clone();
        cluster.run_kernel(kid, move |mut k| {
            // bcast from a non-zero root.
            let data = if kid == 2 { b"from-two".to_vec() } else { Vec::new() };
            let ch = k.bcast(2, &data).unwrap();
            assert_eq!(k.collective_wait(ch).unwrap(), b"from-two".to_vec());

            // reduce(min) to root 1 — only the root sees the fold.
            let mine = [kid as f64 + 0.5];
            let ch = k
                .reduce(1, ReduceOp::Min, Lane::F64, &shoal::collectives::encode_f64s(&mine))
                .unwrap();
            let got = k.collective_wait(ch).unwrap();
            if kid == 1 {
                assert_eq!(shoal::collectives::decode_f64s(&got).unwrap(), vec![0.5]);
            } else {
                assert!(got.is_empty(), "non-root reduce result must be empty");
            }

            // Tree barrier (twice, to exercise consecutive sequences).
            k.barrier_tree().unwrap();
            k.barrier_tree().unwrap();
            tx.send(kid).unwrap();
        });
    }
    drop(tx);
    let mut done: Vec<u16> = (0..4)
        .map(|_| rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap())
        .collect();
    done.sort_unstable();
    assert_eq!(done, vec![0, 1, 2, 3]);
    cluster.join().unwrap();
}

/// Collective handles compose with the generic wait primitives: `wait_all`
/// fences a collective alongside point-to-point operations, and the empty
/// wait contracts hold at the API level.
#[test]
fn collective_handles_compose_with_generic_waits() {
    let spec = ClusterSpec::single_node("c", 2);
    let cluster = ShoalCluster::launch(&spec).unwrap();
    let (tx, rx) = std::sync::mpsc::channel();
    cluster.run_kernel(1, move |mut k| {
        let ch = k.all_reduce_u64(ReduceOp::Sum, &[5]).unwrap();
        let v = k.collective_wait_u64(ch).unwrap();
        tx.send(v).unwrap();
    });
    cluster.run_kernel(0, move |mut k| {
        // wait_all of nothing is a vacuous fence; wait_any of nothing is a
        // typed error (the audited contract).
        k.wait_all(&[]).unwrap();
        let err = k.wait_any(&[]).unwrap_err();
        assert!(matches!(err, shoal::Error::EmptyWaitSet("wait_any")), "{err}");

        // One collective and one put fenced by a single wait_all.
        let put = k.am_long(1, handlers::NOP, &[], &[3u8; 32], 0).unwrap();
        let ch = k.all_reduce_u64(ReduceOp::Sum, &[4]).unwrap();
        k.wait_all(&[put, ch.am]).unwrap();
        // The result is still retrievable after the generic wait consumed
        // the handle.
        assert_eq!(k.collective_wait_u64(ch).unwrap(), vec![9]);
    });
    let v = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
    assert_eq!(v, vec![9]);
    cluster.join().unwrap();
}
