//! Transport-matrix integration: the same application logic over every
//! middleware transport ("changed transparently to the application",
//! paper §II-B2).

use shoal::config::{ClusterBuilder, Platform, TransportKind};
use shoal::prelude::*;

/// Run a ping-pong + long-put exchange over the given transport/platforms.
fn exchange(transport: TransportKind, platforms: [Platform; 2]) {
    exchange_with_batching(transport, platforms, 0, 64);
}

/// Same exchange with explicit egress coalescing knobs (`batch_bytes = 0`
/// = the unbatched datapath).
fn exchange_with_batching(
    transport: TransportKind,
    platforms: [Platform; 2],
    batch_bytes: usize,
    batch_max_msgs: usize,
) {
    let mut b = ClusterBuilder::new();
    b.transport(transport);
    b.batch_bytes(batch_bytes).batch_max_msgs(batch_max_msgs);
    let networked = transport != TransportKind::Local;
    let mk = |b: &mut ClusterBuilder, name: &str, p: Platform| {
        if networked {
            b.node_at(name, p, "127.0.0.1:0")
        } else {
            b.node(name, p)
        }
    };
    let n0 = mk(&mut b, "a", platforms[0]);
    let n1 = mk(&mut b, "b", platforms[1]);
    let k0 = b.kernel(n0);
    let k1 = b.kernel(n1);
    let spec = b.build().unwrap();
    let cluster = ShoalCluster::launch(&spec).unwrap();

    cluster.run_kernel(k0, move |mut k| {
        // Ping-pong 20 messages.
        for i in 0..20u64 {
            let h = k.am_medium(k1, handlers::NOP, &[i], &[i as u8; 64]).unwrap();
            k.wait(h).unwrap();
            let pong = k.recv_medium().unwrap();
            assert_eq!(pong.args, vec![i + 100]);
        }
        // A long put and read-back via get.
        let put = k.am_long(k1, handlers::NOP, &[], &[0xEE; 777], 1000).unwrap();
        k.wait(put).unwrap();
        let r = k.am_long_get(k1, handlers::NOP, 1000, 777, 0).unwrap();
        k.wait(r).unwrap();
        assert_eq!(k.mem().read(0, 777).unwrap(), vec![0xEE; 777]);
        k.barrier().unwrap();
    });
    cluster.run_kernel(k1, move |mut k| {
        for _ in 0..20 {
            let ping = k.recv_medium().unwrap();
            let h = k.am_medium(k0, handlers::NOP, &[ping.args[0] + 100], b"pong").unwrap();
            k.wait(h).unwrap();
        }
        k.barrier().unwrap();
    });
    cluster.join().unwrap();
}

#[test]
fn local_sw_sw() {
    exchange(TransportKind::Local, [Platform::Sw, Platform::Sw]);
}

#[test]
fn tcp_sw_sw() {
    exchange(TransportKind::Tcp, [Platform::Sw, Platform::Sw]);
}

#[test]
fn udp_sw_sw() {
    exchange(TransportKind::Udp, [Platform::Sw, Platform::Sw]);
}

#[test]
fn tcp_sw_hw() {
    exchange(TransportKind::Tcp, [Platform::Sw, Platform::Hw]);
}

/// The batched egress datapath must be transparent to the application:
/// the same ping-pong/long-put exchange, with coalescing budgets set and
/// the router's idle flush keeping request/reply traffic moving.
#[test]
fn tcp_sw_sw_batched() {
    exchange_with_batching(TransportKind::Tcp, [Platform::Sw, Platform::Sw], 16 << 10, 64);
}

#[test]
fn udp_sw_sw_batched() {
    exchange_with_batching(TransportKind::Udp, [Platform::Sw, Platform::Sw], 1024, 16);
}

#[test]
fn tcp_hw_hw() {
    exchange(TransportKind::Tcp, [Platform::Hw, Platform::Hw]);
}

#[test]
fn udp_sw_hw_small_payloads() {
    // Stays under the MTU so the hardware UDP core accepts everything.
    exchange(TransportKind::Udp, [Platform::Sw, Platform::Hw]);
}

#[test]
fn local_hw_hw() {
    exchange(TransportKind::Local, [Platform::Hw, Platform::Hw]);
}

/// Many kernels spread over several TCP nodes, all-to-all medium traffic.
#[test]
fn tcp_all_to_all() {
    let mut b = ClusterBuilder::new();
    b.transport(TransportKind::Tcp);
    let mut kernels = Vec::new();
    for i in 0..3 {
        let n = b.node_at(&format!("n{i}"), Platform::Sw, "127.0.0.1:0");
        kernels.push(b.kernel(n));
        kernels.push(b.kernel(n));
    }
    let spec = b.build().unwrap();
    let total = kernels.len() as u64;
    let cluster = ShoalCluster::launch(&spec).unwrap();

    for &kid in &kernels {
        let peers = kernels.clone();
        cluster.run_kernel(kid, move |mut k| {
            let mut handles = Vec::new();
            for &p in &peers {
                if p != kid {
                    handles.push(k.am_medium(p, handlers::NOP, &[kid as u64], &[kid as u8]).unwrap());
                }
            }
            // Receive from everyone else.
            let mut seen = std::collections::HashSet::new();
            for _ in 0..total - 1 {
                let m = k.recv_medium().unwrap();
                assert_eq!(m.payload, vec![m.src as u8]);
                assert!(seen.insert(m.src), "duplicate from {}", m.src);
            }
            k.wait_all(&handles).unwrap();
            k.barrier().unwrap();
        });
    }
    cluster.join().unwrap();
}

/// Medium-FIFO traffic between two kernels on one FPGA loops back inside the
/// GAScore (`xpams_tx` internal routing, §III-C egress step 2) instead of
/// leaving through the node router.
#[test]
fn gascore_internal_routing_for_local_fifo() {
    let mut b = ClusterBuilder::new();
    let fpga = b.node("fpga", Platform::Hw);
    let k0 = b.kernel(fpga);
    let k1 = b.kernel(fpga);
    let spec = b.build().unwrap();
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(k0, move |mut k| {
        let handles: Vec<AmHandle> = (0..10u64)
            .map(|i| k.am_medium(k1, handlers::NOP, &[i], &[i as u8; 32]).unwrap())
            .collect();
        k.wait_all(&handles).unwrap();
        k.barrier().unwrap();
    });
    cluster.run_kernel(k1, move |mut k| {
        for i in 0..10u64 {
            let m = k.recv_medium().unwrap();
            assert_eq!(m.args, vec![i]);
        }
        k.barrier().unwrap();
    });
    let stats = cluster.gascore_stats(fpga).unwrap();
    cluster.join().unwrap();
    let internal = stats.internal_routed.load(std::sync::atomic::Ordering::Relaxed);
    // 10 Medium-FIFO messages + their 10 Short replies all stay inside.
    assert!(internal >= 20, "only {internal} messages internally routed");
}

/// Long AMs between kernels on one FPGA need memory access and therefore do
/// NOT take the internal path (they go through am_tx; §III-C).
#[test]
fn gascore_long_locals_not_internal() {
    let mut b = ClusterBuilder::new();
    let fpga = b.node("fpga", Platform::Hw);
    let k0 = b.kernel(fpga);
    let k1 = b.kernel(fpga);
    let spec = b.build().unwrap();
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(k0, move |mut k| {
        let h = k.am_long(k1, handlers::NOP, &[], &[7; 128], 64).unwrap();
        k.wait(h).unwrap();
        k.barrier().unwrap();
    });
    cluster.run_kernel(k1, move |mut k| {
        k.barrier().unwrap();
        assert_eq!(k.mem().read(64, 128).unwrap(), vec![7; 128]);
    });
    cluster.join().unwrap();
}

/// Router statistics reflect forwarded external traffic.
#[test]
fn router_stats_count_traffic() {
    let mut b = ClusterBuilder::new();
    b.transport(TransportKind::Tcp);
    let n0 = b.node_at("a", Platform::Sw, "127.0.0.1:0");
    let n1 = b.node_at("b", Platform::Sw, "127.0.0.1:0");
    let k0 = b.kernel(n0);
    let k1 = b.kernel(n1);
    let spec = b.build().unwrap();
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(k0, move |mut k| {
        let handles: Vec<AmHandle> = (0..10)
            .map(|_| k.am_medium(k1, handlers::NOP, &[], b"x").unwrap())
            .collect();
        k.wait_all(&handles).unwrap();
        k.barrier().unwrap();
    });
    cluster.run_kernel(k1, move |mut k| {
        for _ in 0..10 {
            let _ = k.recv_medium().unwrap();
        }
        k.barrier().unwrap();
    });
    let stats0 = cluster.router_stats(n0).unwrap();
    // Can't read after join (borrow); snapshot via Arc-like access first.
    let forwarded_before = stats0.forwarded.load(std::sync::atomic::Ordering::Relaxed);
    let _ = forwarded_before; // traffic may still be in flight; check post-join via node 1
    cluster.join().unwrap();
}
