//! End-to-end distributed Jacobi vs. the serial oracle.

use shoal::apps::jacobi::{compute, run_with_grid, JacobiConfig};

fn rand_grid(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = shoal::util::rng::Rng::new(seed);
    (0..n * n).map(|_| rng.f32_range(-1.0, 1.0)).collect()
}

fn check(cfg: JacobiConfig, grid: Vec<f32>) {
    let report = run_with_grid(&cfg, grid.clone()).unwrap();
    report.verify(&grid).unwrap();
    assert_eq!(report.worker_reports.len(), cfg.workers);
    assert!(report.wall.as_nanos() > 0);
}

#[test]
fn sw_single_worker() {
    let cfg = JacobiConfig { n: 18, iters: 10, workers: 1, ..Default::default() };
    check(cfg, rand_grid(18, 1));
}

#[test]
fn sw_four_workers_one_node() {
    let cfg = JacobiConfig { n: 34, iters: 12, workers: 4, ..Default::default() };
    check(cfg, rand_grid(34, 2));
}

#[test]
fn sw_uneven_strips() {
    // 30 interior rows over 7 workers: strips of 5 and 4 rows.
    let cfg = JacobiConfig { n: 32, iters: 8, workers: 7, ..Default::default() };
    check(cfg, rand_grid(32, 3));
}

#[test]
fn sw_workers_across_two_nodes() {
    let cfg = JacobiConfig { n: 34, iters: 10, workers: 4, nodes: 2, ..Default::default() };
    check(cfg, rand_grid(34, 4));
}

#[test]
fn hw_workers_match_oracle() {
    // Tile shapes must exist as artifacts: 32×64 tiles → grid 66, 2 workers.
    let cfg = JacobiConfig { n: 66, iters: 6, workers: 2, hw: true, ..Default::default() };
    check(cfg, rand_grid(66, 5));
}

#[test]
fn hw_two_fpgas() {
    // 16×32 tiles → grid 34, 2 workers over 2 "FPGAs".
    let cfg =
        JacobiConfig { n: 34, iters: 6, workers: 2, nodes: 2, hw: true, ..Default::default() };
    check(cfg, rand_grid(34, 6));
}

#[test]
fn hw_missing_artifact_is_a_clear_error() {
    let cfg = JacobiConfig { n: 30, iters: 2, workers: 2, hw: true, ..Default::default() };
    let err = run_with_grid(&cfg, rand_grid(30, 7)).unwrap_err();
    assert!(matches!(err, shoal::Error::Artifact(_)), "{err}");
    assert!(err.to_string().contains("14x30"), "{err}");
}

#[test]
fn heat_diffusion_physics() {
    // Hot top plate diffuses downward; interior stays within bounds.
    let n = 34;
    let grid = compute::hot_plate(n, n);
    let cfg = JacobiConfig { n, iters: 100, workers: 4, ..Default::default() };
    let report = run_with_grid(&cfg, grid.clone()).unwrap();
    report.verify(&grid).unwrap();
    // Row 1 (just under the hot edge) is warmer than row n-2.
    let row = |r: usize| -> f32 {
        report.grid[r * n..(r + 1) * n].iter().sum::<f32>() / n as f32
    };
    assert!(row(1) > row(n - 2));
    assert!(report.grid.iter().all(|&v| (0.0..=100.0).contains(&v)));
}

#[test]
fn tolerance_run_converges_in_fewer_sweeps_than_budget() {
    // The paper's solver runs a fixed iteration count because the counter
    // barrier carries no data; with `all_reduce(max residual)` the cluster
    // detects convergence globally and stops early. A fixed-budget run
    // executes exactly `iters` sweeps by construction, so converging below
    // the budget is strictly fewer sweeps — on the 4-worker software
    // cluster the acceptance criterion names.
    let n = 18;
    let budget = 600;
    let cfg = JacobiConfig {
        n,
        iters: budget,
        workers: 4,
        tolerance: Some(1.0),
        check_every: 8,
        ..Default::default()
    };
    let grid = compute::hot_plate(n, n);
    let report = run_with_grid(&cfg, grid.clone()).unwrap();
    assert!(report.converged, "residual never reached tolerance");
    assert!(
        report.iters_done < budget,
        "converged run used the whole budget ({} sweeps)",
        report.iters_done
    );
    assert_eq!(report.iters_done % 8, 0, "stops only at a convergence check");
    // Workers agree with control on when they stopped.
    for w in &report.worker_reports {
        assert_eq!(w.iters_done, report.iters_done, "worker {} diverged", w.worker);
    }
    // The early-stopped grid still matches the serial oracle at the same
    // sweep count.
    report.verify(&grid).unwrap();
}

#[test]
fn fixed_budget_run_reports_full_iteration_count() {
    let cfg = JacobiConfig { n: 18, iters: 10, workers: 2, ..Default::default() };
    let report = run_with_grid(&cfg, rand_grid(18, 11)).unwrap();
    assert_eq!(report.iters_done, 10);
    assert!(!report.converged);
}

#[test]
fn oversized_halo_fails_without_chunking() {
    // Grid 4096 → rows of 16 KiB > the 9000 B Galapagos cap. The paper hits
    // exactly this (§IV-C1: "too large to send in a single AM ... has not
    // been implemented"); the run must fail fast, not hang.
    let n = 4096;
    let cfg = JacobiConfig { n, iters: 1, workers: 2, ..Default::default() };
    let grid = vec![0f32; n * n];
    let err = run_with_grid(&cfg, grid).unwrap_err();
    assert!(matches!(err, shoal::Error::AmTooLarge { .. }), "{err}");
}

#[test]
fn chunked_run_matches_oracle() {
    // With the chunking extension enabled (the paper's proposed fix,
    // implemented here), runs whose distribution AMs exceed one packet work
    // and still match the oracle. 64×64 tiles are 16 KiB → 2 chunks each.
    let n = 66;
    let cfg = JacobiConfig { n, iters: 4, workers: 1, chunked: true, ..Default::default() };
    check(cfg, rand_grid(n, 8));
}
