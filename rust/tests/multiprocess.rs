//! Real multi-process deployment: this test process hosts node 0 of a
//! two-node TCP cluster and spawns `shoal serve` as a *separate OS process*
//! hosting node 1 — the Galapagos model of one runtime per machine.

use std::io::Write;
use std::process::{Child, Command, Stdio};

use shoal::config::parse::parse_cluster;
use shoal::prelude::*;
use shoal::shoal_node::cluster::ShoalCluster;

/// Guard serializing port allocation + binding across parallel tests —
/// otherwise another test's bind-and-drop can recycle a port in the window
/// between `free_ports` releasing it and the cluster re-binding it.
static PORT_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Pick two free loopback ports by binding-and-dropping listeners.
fn free_ports() -> (u16, u16) {
    let a = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let b = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    (a.local_addr().unwrap().port(), b.local_addr().unwrap().port())
}

fn cluster_file(transport: &str, p0: u16, p1: u16) -> String {
    format!(
        r#"
transport = "{transport}"

[[node]]
name = "driver"
platform = "sw"
address = "127.0.0.1:{p0}"

[[node]]
name = "server"
platform = "sw"
address = "127.0.0.1:{p1}"

[[kernel]]
node = "driver"

[[kernel]]
node = "server"
count = 2
"#
    )
}

fn spawn_server(path: &std::path::Path, node: u16, app: &str, max_msgs: u64) -> Child {
    Command::new(env!("CARGO_BIN_EXE_shoal"))
        .args([
            "serve",
            "--cluster",
            path.to_str().unwrap(),
            "--node",
            &node.to_string(),
            "--app",
            app,
            "--max-msgs",
            &max_msgs.to_string(),
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn shoal serve")
}

/// Like `spawn_server` but for the gups app, which takes its workload shape
/// on the command line; driver and servers must agree on `--updates` or the
/// app's own exactness fold fails.
fn spawn_gups_server(path: &std::path::Path, node: u16, updates: usize, table_words: u64) -> Child {
    Command::new(env!("CARGO_BIN_EXE_shoal"))
        .args([
            "serve",
            "--cluster",
            path.to_str().unwrap(),
            "--node",
            &node.to_string(),
            "--app",
            "gups",
            "--updates",
            &updates.to_string(),
            "--table-words",
            &table_words.to_string(),
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn shoal serve --app gups")
}

#[test]
fn two_process_echo_over_tcp() {
    let _guard = PORT_LOCK.lock().unwrap();
    let (p0, p1) = free_ports();
    let text = cluster_file("tcp", p0, p1);
    let spec = parse_cluster(&text).unwrap();

    // Write the cluster file for the server process.
    let dir = std::env::temp_dir().join(format!("shoal-mp-{p0}-{p1}"));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cluster.toml");
    let mut f = std::fs::File::create(&path).unwrap();
    f.write_all(text.as_bytes()).unwrap();
    drop(f);

    const MSGS: u64 = 25;
    let mut server = spawn_server(&path, 1, "echo", MSGS);

    // Host node 0 in this process and drive both remote kernels.
    let cluster = ShoalCluster::launch_node(&spec, 0).unwrap();
    let (tx, rx) = std::sync::mpsc::channel();
    cluster.run_kernel(0, move |mut k| {
        for target in [1u16, 2] {
            for i in 0..MSGS {
                let h = k
                    .am_medium(target, handlers::NOP, &[i], format!("msg-{i}").as_bytes())
                    .unwrap();
                // Echo comes back asynchronously on our stream; the put
                // itself is acked.
                k.wait(h).unwrap();
                let echo = k.recv_medium().unwrap();
                assert_eq!(echo.src, target);
                assert_eq!(echo.args, vec![i]);
                assert_eq!(echo.payload, format!("msg-{i}").into_bytes());
            }
        }
        tx.send(()).unwrap();
    });
    rx.recv_timeout(std::time::Duration::from_secs(60))
        .expect("driver finished");
    cluster.join().unwrap();

    let status = server.wait().expect("server exits after max-msgs");
    assert!(status.success(), "server exit: {status:?}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Cross-transport tree collectives over real processes: this process hosts
/// node 0 (one kernel, the tree root) while a spawned `shoal serve --app
/// allreduce` hosts node 1 (two kernels); all three kernels join one
/// all-reduce of their kernel ids — over TCP and again over UDP.
#[test]
fn cross_transport_all_reduce() {
    for transport in ["tcp", "udp"] {
        let _guard = PORT_LOCK.lock().unwrap();
        let (p0, p1) = free_ports();
        let text = cluster_file(transport, p0, p1);
        let spec = parse_cluster(&text).unwrap();

        let dir = std::env::temp_dir().join(format!("shoal-mp-ar-{transport}-{p0}-{p1}"));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cluster.toml");
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(text.as_bytes()).unwrap();
        drop(f);

        let mut server = spawn_server(&path, 1, "allreduce", 0);
        let cluster = ShoalCluster::launch_node(&spec, 0).unwrap();
        let (tx, rx) = std::sync::mpsc::channel();
        cluster.run_kernel(0, move |mut k| {
            // Readiness handshake (UDP has no retransmit): each remote
            // kernel repeats hello until released, so once we have heard
            // from both, every socket is bound and no collective message
            // can be dropped on an unbound port.
            let mut seen = std::collections::HashSet::new();
            while seen.len() < 2 {
                seen.insert(k.recv_medium().unwrap().src);
            }
            for kid in [1u16, 2] {
                let _ = k.am_medium_async(kid, handlers::NOP, &[], b"go").unwrap();
            }
            let ch = k.all_reduce_u64(ReduceOp::Sum, &[k.id() as u64]).unwrap();
            let v = k.collective_wait_u64(ch).unwrap();
            tx.send(v).unwrap();
        });
        let v = rx
            .recv_timeout(std::time::Duration::from_secs(60))
            .unwrap_or_else(|_| panic!("all-reduce over {transport} timed out"));
        // Kernel ids 0, 1, 2 → sum 3.
        assert_eq!(v, vec![3], "fold of kernel ids over {transport}");
        cluster.join().unwrap();

        let status = server.wait().expect("server exits after the collective");
        assert!(status.success(), "server exit over {transport}: {status:?}");
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Cross-process remote atomics: `shoal serve --app gups` hosts node 1's two
/// kernels while this process hosts node 0 (kernel 0, the handshake
/// coordinator); all three kernels hammer each other's tables with windowed
/// fetch-and-adds through the Rma tier, then the app's own all-reduce fold
/// asserts not one update was lost or double-applied — over TCP and UDP.
#[test]
fn cross_transport_gups() {
    const UPDATES: usize = 600;
    const TABLE_WORDS: u64 = 256;
    for transport in ["tcp", "udp"] {
        let _guard = PORT_LOCK.lock().unwrap();
        let (p0, p1) = free_ports();
        let text = cluster_file(transport, p0, p1);
        let spec = parse_cluster(&text).unwrap();

        let dir = std::env::temp_dir().join(format!("shoal-mp-gups-{transport}-{p0}-{p1}"));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cluster.toml");
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(text.as_bytes()).unwrap();
        drop(f);

        let mut server = spawn_gups_server(&path, 1, UPDATES, TABLE_WORDS);
        let cluster = ShoalCluster::launch_node(&spec, 0).unwrap();
        let (tx, rx) = std::sync::mpsc::channel();
        cluster.run_kernel(0, move |mut k| {
            // Same readiness handshake as the allreduce app: gups opens with
            // a barrier, and a barrier message to an unbound UDP port would
            // be lost with no retransmit.
            let mut seen = std::collections::HashSet::new();
            while seen.len() < 2 {
                seen.insert(k.recv_medium().unwrap().src);
            }
            for kid in [1u16, 2] {
                let _ = k.am_medium_async(kid, handlers::NOP, &[], b"go").unwrap();
            }
            let rate = shoal::apps::gups::kernel_body(&mut k, &[0, 1, 2], UPDATES, TABLE_WORDS)
                .expect("gups exactness fold");
            tx.send(rate).unwrap();
        });
        let rate = rx
            .recv_timeout(std::time::Duration::from_secs(120))
            .unwrap_or_else(|_| panic!("gups over {transport} timed out"));
        assert!(rate > 0.0, "gups rate over {transport}");
        cluster.join().unwrap();

        let status = server.wait().expect("server exits after the fold");
        assert!(status.success(), "server exit over {transport}: {status:?}");
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn launch_node_rejects_local_transport() {
    let spec = shoal::config::ClusterSpec::single_node("n", 1);
    assert!(ShoalCluster::launch_node(&spec, 0).is_err());
}

#[test]
fn launch_node_rejects_unknown_node() {
    let _guard = PORT_LOCK.lock().unwrap();
    let (p0, p1) = free_ports();
    let spec = parse_cluster(&cluster_file("tcp", p0, p1)).unwrap();
    assert!(matches!(
        ShoalCluster::launch_node(&spec, 9),
        Err(shoal::Error::UnknownNode(9))
    ));
}
