//! Property-based tests over the library's core invariants.
//!
//! Uses the in-crate mini framework (`shoal::util::proptest`): seeded random
//! cases with failing-seed reporting (`SHOAL_PROP_SEED` to replay).

use shoal::am::header::{AmMessage, Descriptor, MAX_VECTORED};
use shoal::am::types::{AmFlags, AmType, AtomicOp};
use shoal::collectives::Lane;
use shoal::collectives::{CollectiveTree, ReduceOp, TreeKind};
use shoal::galapagos::packet::{Packet, MAX_PAYLOAD_BYTES};
use shoal::galapagos::router::RoutingTable;
use shoal::memory::Segment;
use shoal::prelude::ShoalCluster;
use shoal::util::proptest::check;
use shoal::util::rng::Rng;
use shoal::{prop_assert, prop_assert_eq};

/// Build a random-but-valid AM.
fn random_am(rng: &mut Rng) -> AmMessage {
    let am_type = *rng.pick(&[
        AmType::Short,
        AmType::Medium,
        AmType::Long,
        AmType::LongStrided,
        AmType::LongVectored,
        AmType::Atomic,
    ]);
    let mut flags = AmFlags::new();
    if rng.chance(0.3) {
        flags = flags.with(AmFlags::ASYNC);
    }
    if rng.chance(0.3) {
        flags = flags.with(AmFlags::FIFO);
    }
    if rng.chance(0.3) {
        flags = flags.with(AmFlags::HANDLE);
    }
    let nargs = rng.below(9) as usize;
    let args: Vec<u64> = (0..nargs).map(|_| rng.next_u64()).collect();
    let payload_len = rng.below(2048) as usize;

    let (desc, payload, flags) = match am_type {
        AmType::Short => (Descriptor::None, vec![], flags),
        AmType::Medium => {
            if rng.chance(0.3) {
                (
                    Descriptor::MediumGet {
                        src_addr: rng.below(1 << 20),
                        len: rng.below(4096) as u32,
                    },
                    vec![],
                    flags.with(AmFlags::GET),
                )
            } else {
                (Descriptor::None, rng.bytes(payload_len), flags)
            }
        }
        AmType::Long => {
            if rng.chance(0.3) {
                (
                    Descriptor::LongGet {
                        src_addr: rng.below(1 << 20),
                        len: rng.below(4096) as u32,
                        reply_addr: rng.below(1 << 20),
                    },
                    vec![],
                    flags.with(AmFlags::GET),
                )
            } else {
                (
                    Descriptor::Long { dst_addr: rng.below(1 << 30) },
                    rng.bytes(payload_len),
                    flags,
                )
            }
        }
        AmType::LongStrided => {
            let block_len = rng.range(1, 64) as u32;
            let nblocks = rng.range(1, 16) as u32;
            let stride = block_len + rng.below(64) as u32;
            (
                Descriptor::Strided {
                    dst_addr: rng.below(1 << 20),
                    stride,
                    block_len,
                    nblocks,
                },
                rng.bytes((block_len * nblocks) as usize),
                flags,
            )
        }
        AmType::LongVectored => {
            let count = rng.range(1, MAX_VECTORED as u64) as usize;
            let entries: Vec<(u64, u32)> = (0..count)
                .map(|_| (rng.below(1 << 20), rng.range(0, 64) as u32))
                .collect();
            let total: usize = entries.iter().map(|(_, l)| *l as usize).sum();
            (Descriptor::Vectored { entries }, rng.bytes(total), flags)
        }
        AmType::Atomic => {
            // Scalar ops carry no payload (U64 lane); accumulates carry a
            // non-empty multiple-of-8 element vector in either lane.
            let op = *rng.pick(&[
                AtomicOp::FaaAdd,
                AtomicOp::FaaMin,
                AtomicOp::FaaMax,
                AtomicOp::FaaAnd,
                AtomicOp::FaaOr,
                AtomicOp::FaaXor,
                AtomicOp::Cas,
                AtomicOp::Swap,
                AtomicOp::AccSum,
                AtomicOp::AccMin,
                AtomicOp::AccMax,
            ]);
            let (lane, payload) = if op.is_accumulate() {
                let lane = *rng.pick(&[Lane::U64, Lane::F64]);
                let words = rng.range(1, 64) as usize;
                (lane, rng.bytes(words * 8))
            } else {
                (Lane::U64, vec![])
            };
            (
                Descriptor::Atomic {
                    addr: rng.below(1 << 30),
                    op,
                    lane,
                    operand: rng.next_u64(),
                    operand2: rng.next_u64(),
                },
                payload,
                flags,
            )
        }
    };

    AmMessage {
        am_type,
        flags,
        src: rng.next_u32() as u16,
        dst: rng.next_u32() as u16,
        handler: rng.next_u32() as u8,
        token: rng.next_u32(),
        args,
        desc,
        payload,
    }
}

#[test]
fn prop_am_codec_roundtrip() {
    check("am-codec-roundtrip", 2000, |rng| {
        let msg = random_am(rng);
        let wire = msg.encode().map_err(|e| format!("encode: {e}"))?;
        let back = AmMessage::decode(&wire).map_err(|e| format!("decode: {e}"))?;
        prop_assert_eq!(msg, back);
        Ok(())
    });
}

/// The zero-copy send path's contract: `WireBuilder` borrowed-slice
/// encoding is bitwise identical to the owned `AmMessage::encode` across
/// all five AM classes (both the slice and the fill-into-tail variants) —
/// a remote peer cannot tell which path produced a packet.
#[test]
fn prop_borrowed_encode_bitwise_identical_to_owned() {
    check("borrowed-encode-identical", 2000, |rng| {
        let msg = random_am(rng);
        let owned = msg.encode().map_err(|e| format!("owned encode: {e}"))?;
        let (wb, payload) = msg.as_wire();
        let mut via_slice = Vec::new();
        wb.encode_slice(payload, &mut via_slice)
            .map_err(|e| format!("borrowed encode: {e}"))?;
        prop_assert_eq!(owned, via_slice);
        let mut via_fill = Vec::new();
        wb.encode_with(payload.len(), &mut via_fill, |out| {
            out.copy_from_slice(payload);
            Ok(())
        })
        .map_err(|e| format!("fill encode: {e}"))?;
        prop_assert_eq!(via_slice, via_fill);
        // Overheads agree too (the chunking bound must not drift).
        prop_assert_eq!(wb.header_overhead(), msg.header_overhead());
        prop_assert_eq!(wb.max_payload(), msg.max_payload_for());
        Ok(())
    });
}

/// The completion subsystem rides on the codec preserving the reply token,
/// the HANDLE/REPLY flag bits and the message class bit-exactly for *every*
/// AM class — a dropped token orphans an `AmHandle` forever.
#[test]
fn prop_reply_token_flags_class_roundtrip() {
    check("reply-token-roundtrip", 2000, |rng| {
        for &am_type in &[
            AmType::Short,
            AmType::Medium,
            AmType::Long,
            AmType::LongStrided,
            AmType::LongVectored,
            AmType::Atomic,
        ] {
            let mut flags = AmFlags::new();
            if rng.chance(0.5) {
                flags = flags.with(AmFlags::REPLY);
            }
            if rng.chance(0.5) {
                flags = flags.with(AmFlags::HANDLE);
            }
            let token = rng.next_u32();
            let nargs = rng.below(9) as usize;
            let args: Vec<u64> = (0..nargs).map(|_| rng.next_u64()).collect();
            let (desc, payload) = match am_type {
                AmType::Short => (Descriptor::None, Vec::new()),
                AmType::Medium => (Descriptor::None, rng.bytes(16)),
                AmType::Long => {
                    (Descriptor::Long { dst_addr: rng.next_u64() }, rng.bytes(32))
                }
                AmType::LongStrided => (
                    Descriptor::Strided {
                        dst_addr: rng.below(1 << 20),
                        stride: 16,
                        block_len: 8,
                        nblocks: 4,
                    },
                    rng.bytes(32),
                ),
                AmType::LongVectored => (
                    Descriptor::Vectored { entries: vec![(rng.below(1 << 20), 32)] },
                    rng.bytes(32),
                ),
                // The fetch-reply path rides on these exact bits: a mangled
                // token or HANDLE flag orphans the FetchHandle.
                AmType::Atomic => (
                    Descriptor::Atomic {
                        addr: rng.below(1 << 20),
                        op: *rng.pick(&[AtomicOp::FaaAdd, AtomicOp::Cas, AtomicOp::Swap]),
                        lane: Lane::U64,
                        operand: rng.next_u64(),
                        operand2: rng.next_u64(),
                    },
                    Vec::new(),
                ),
            };
            let msg = AmMessage {
                am_type,
                flags,
                src: rng.next_u32() as u16,
                dst: rng.next_u32() as u16,
                handler: rng.next_u32() as u8,
                token,
                args,
                desc,
                payload,
            };
            let wire = msg.encode().map_err(|e| format!("encode {am_type}: {e}"))?;
            let back = AmMessage::decode(&wire).map_err(|e| format!("decode {am_type}: {e}"))?;
            prop_assert_eq!(back.token, token);
            prop_assert_eq!(back.flags, msg.flags);
            prop_assert_eq!(back.am_type, am_type);
            prop_assert_eq!(back.args, msg.args);
        }
        Ok(())
    });
}

#[test]
fn prop_am_decode_never_panics_on_garbage() {
    check("am-decode-garbage", 5000, |rng| {
        let len = rng.below(256) as usize;
        let buf = rng.bytes(len);
        let _ = AmMessage::decode(&buf); // must return, never panic
        Ok(())
    });
}

#[test]
fn prop_am_decode_survives_truncation_and_bitflips() {
    check("am-decode-mutation", 1000, |rng| {
        let msg = random_am(rng);
        let mut wire = msg.encode().map_err(|e| format!("{e}"))?;
        if rng.chance(0.5) && !wire.is_empty() {
            let cut = rng.below(wire.len() as u64) as usize;
            wire.truncate(cut);
        } else if !wire.is_empty() {
            let i = rng.below(wire.len() as u64) as usize;
            wire[i] ^= 1 << rng.below(8);
        }
        let _ = AmMessage::decode(&wire); // any Result is fine; no panic/OOM
        Ok(())
    });
}

#[test]
fn prop_packet_wire_roundtrip() {
    check("packet-roundtrip", 2000, |rng| {
        let len = rng.below(MAX_PAYLOAD_BYTES as u64 + 1) as usize;
        let pkt = Packet::new(rng.next_u32() as u16, rng.next_u32() as u16, rng.bytes(len))
            .map_err(|e| format!("{e}"))?;
        let back = Packet::from_wire(&pkt.to_wire()).map_err(|e| format!("{e}"))?;
        prop_assert_eq!(pkt, back);
        Ok(())
    });
}

#[test]
fn prop_allocator_never_overlaps() {
    check("allocator-no-overlap", 300, |rng| {
        let seg = Segment::new(1 << 16);
        let mut live: Vec<(u64, usize)> = Vec::new();
        for _ in 0..64 {
            if !live.is_empty() && rng.chance(0.4) {
                let i = rng.below(live.len() as u64) as usize;
                let (off, _) = live.swap_remove(i);
                seg.free(off).map_err(|e| format!("free: {e}"))?;
            } else {
                let len = rng.range(1, 2048) as usize;
                if let Ok(off) = seg.alloc(len) {
                    for &(o, l) in &live {
                        let disjoint = off + len as u64 <= o || o + l as u64 <= off;
                        prop_assert!(disjoint, "overlap: new ({off},{len}) vs live ({o},{l})");
                    }
                    live.push((off, len));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_alloc_free_alloc_converges() {
    check("allocator-coalesce", 200, |rng| {
        let size = 1 << 14;
        let seg = Segment::new(size);
        let mut offs = Vec::new();
        for _ in 0..16 {
            if let Ok(o) = seg.alloc(rng.range(8, 512) as usize) {
                offs.push(o);
            }
        }
        rng.shuffle(&mut offs);
        for o in offs {
            seg.free(o).map_err(|e| format!("{e}"))?;
        }
        // After freeing everything, the full segment must be allocatable.
        let o = seg.alloc(size).map_err(|e| format!("full realloc: {e}"))?;
        prop_assert_eq!(o, 0);
        Ok(())
    });
}

#[test]
fn prop_strided_equals_naive_scatter() {
    check("strided-vs-naive", 500, |rng| {
        let seg_a = Segment::new(1 << 14);
        let seg_b = Segment::new(1 << 14);
        let block_len = rng.range(1, 64) as u32;
        let nblocks = rng.range(1, 16) as u32;
        let stride = block_len + rng.below(32) as u32;
        let base = rng.below(256);
        let span = (nblocks - 1) as u64 * stride as u64 + block_len as u64;
        if base + span > (1 << 14) {
            return Ok(()); // out of range; covered by bounds tests
        }
        let data = rng.bytes((block_len * nblocks) as usize);
        seg_a
            .write_strided(base, stride, block_len, &data)
            .map_err(|e| format!("{e}"))?;
        for i in 0..nblocks {
            let chunk = &data[(i * block_len) as usize..((i + 1) * block_len) as usize];
            seg_b
                .write(base + (i * stride) as u64, chunk)
                .map_err(|e| format!("{e}"))?;
        }
        let a = seg_a.read(base, span as usize).map_err(|e| format!("{e}"))?;
        let b = seg_b.read(base, span as usize).map_err(|e| format!("{e}"))?;
        prop_assert_eq!(a, b);
        // Gather inverts scatter.
        let back = seg_a
            .read_strided(base, stride, block_len, nblocks)
            .map_err(|e| format!("{e}"))?;
        prop_assert_eq!(back, data);
        Ok(())
    });
}

#[test]
fn prop_vectored_equals_naive() {
    check("vectored-vs-naive", 500, |rng| {
        let seg = Segment::new(1 << 14);
        let count = rng.range(1, 8) as usize;
        // Non-overlapping extents in disjoint 1 KiB lanes.
        let entries: Vec<(u64, u32)> = (0..count)
            .map(|i| ((i as u64) * 1024 + rng.below(256), rng.range(1, 256) as u32))
            .collect();
        let total: usize = entries.iter().map(|(_, l)| *l as usize).sum();
        let data = rng.bytes(total);
        seg.write_vectored(&entries, &data).map_err(|e| format!("{e}"))?;
        let back = seg.read_vectored(&entries).map_err(|e| format!("{e}"))?;
        prop_assert_eq!(back, data);
        Ok(())
    });
}

#[test]
fn prop_routing_table_total() {
    check("routing-total", 500, |rng| {
        let kernels = rng.range(1, 64) as u16;
        let nodes = rng.range(1, 8) as u16;
        let entries: Vec<(u16, u16)> =
            (0..kernels).map(|k| (k, rng.below(nodes as u64) as u16)).collect();
        let table = RoutingTable::new(entries.clone());
        for (k, n) in entries {
            prop_assert_eq!(table.node_of(k).map_err(|e| format!("{e}"))?, n);
        }
        prop_assert!(table.node_of(kernels + 1).is_err(), "unknown kernel must error");
        Ok(())
    });
}

/// Router sharding's destination-hash ownership map is a true partition for
/// every shard count 1..=8: each node (and kernel) id maps to exactly one
/// in-range shard, the map is stable call-to-call (egress enqueue and
/// ingress dispatch must agree or a peer's ARQ window would be touched by
/// two reactors), `shards = 1` collapses everything onto shard 0, and
/// contiguous ids spread across shards within one slot of each other.
#[test]
fn prop_shard_ownership_is_a_stable_partition() {
    use shoal::galapagos::router::{shard_of_kernel, shard_of_node};
    check("shard-ownership", 500, |rng| {
        let nodes = rng.range(1, 256) as u16;
        for shards in 1..=8usize {
            let mut counts = vec![0usize; shards];
            for node in 0..nodes {
                let s = shard_of_node(node, shards);
                prop_assert!(s < shards, "shard {s} out of range for {shards} shards");
                // Stable: the send side and the ingress side compute the
                // owner independently; they must always agree.
                prop_assert_eq!(s, shard_of_node(node, shards));
                prop_assert_eq!(s, shard_of_kernel(node, shards));
                if shards == 1 {
                    prop_assert_eq!(s, 0);
                }
                counts[s] += 1;
            }
            // Every node owned by exactly one shard (partition, not cover).
            prop_assert_eq!(counts.iter().sum::<usize>(), nodes as usize);
            // Contiguous ids balance within one slot of each other.
            let min = *counts.iter().min().unwrap();
            let max = *counts.iter().max().unwrap();
            prop_assert!(
                max - min <= 1,
                "unbalanced ownership for {nodes} nodes over {shards} shards: {counts:?}"
            );
        }
        // Sparse ids are still stable and in range.
        for _ in 0..32 {
            let node = rng.next_u32() as u16;
            let shards = rng.range(1, 8) as usize;
            let s = shard_of_node(node, shards);
            prop_assert!(s < shards);
            prop_assert_eq!(s, shard_of_node(node, shards));
        }
        Ok(())
    });
}

#[test]
fn prop_header_overhead_matches_wire() {
    check("header-overhead", 1000, |rng| {
        let msg = random_am(rng);
        let wire = msg.encode().map_err(|e| format!("{e}"))?;
        prop_assert_eq!(wire.len(), msg.header_overhead() + msg.payload.len());
        Ok(())
    });
}

/// Spawn a small single-node software cluster with `n` kernels and tight
/// segments — the harness of the collective properties.
fn small_cluster(n: u16) -> Result<shoal::config::ClusterSpec, String> {
    let mut b = shoal::config::ClusterBuilder::new();
    b.default_segment(1 << 12);
    let node = b.node("prop", shoal::config::Platform::Sw);
    for _ in 0..n {
        b.kernel(node);
    }
    b.build().map_err(|e| format!("{e}"))
}

#[test]
fn prop_collective_tree_is_well_formed_spanning_tree() {
    check("collective-tree-spanning", 500, |rng| {
        // Random, possibly sparse and non-contiguous kernel ids.
        let count = rng.range(1, 64) as usize;
        let mut ids: Vec<u16> = (0..count).map(|_| rng.below(1 << 12) as u16).collect();
        ids.sort_unstable();
        ids.dedup();
        let root = *rng.pick(&ids);
        for kind in [TreeKind::Binomial, TreeKind::Binary] {
            let tree =
                CollectiveTree::new(ids.clone(), root, kind).map_err(|e| format!("{e}"))?;
            prop_assert_eq!(tree.root(), root);
            prop_assert_eq!(tree.len(), ids.len());
            // Every non-root has exactly one parent; parent links agree with
            // children lists; walking parents reaches the root acyclically.
            for &id in &ids {
                let p = tree.parent(id).map_err(|e| format!("{e}"))?;
                if id == root {
                    prop_assert!(p.is_none(), "root {root} must have no parent");
                    continue;
                }
                let parent = p.ok_or_else(|| format!("non-root {id} has no parent"))?;
                let siblings = tree.children(parent).map_err(|e| format!("{e}"))?;
                prop_assert!(
                    siblings.contains(&id),
                    "parent {parent} does not list child {id}"
                );
                let mut cur = id;
                let mut hops = 0usize;
                while cur != root {
                    cur = tree
                        .parent(cur)
                        .map_err(|e| format!("{e}"))?
                        .ok_or_else(|| format!("dangling non-root {cur}"))?;
                    hops += 1;
                    prop_assert!(hops <= ids.len(), "cycle walking from {id} to the root");
                }
            }
            // Children lists partition the non-root ids: no kernel has two
            // parents, nobody claims the root, everyone is claimed once.
            let mut seen = std::collections::HashSet::new();
            for &id in &ids {
                for c in tree.children(id).map_err(|e| format!("{e}"))? {
                    prop_assert!(c != root, "root listed as a child of {id}");
                    prop_assert!(seen.insert(c), "kernel {c} has two parents");
                }
            }
            prop_assert_eq!(seen.len(), ids.len() - 1);
        }
        Ok(())
    });
}

#[test]
fn prop_all_reduce_equals_scalar_fold_on_every_kernel() {
    // Random cluster sizes 1..=16, random ops, random lane counts: the
    // all-reduce every kernel observes must equal the serial fold.
    check("all-reduce-fold", 8, |rng| {
        let n = rng.range(1, 16) as u16;
        let lanes = rng.range(1, 4) as usize;
        let op = *rng.pick(&[ReduceOp::Sum, ReduceOp::Min, ReduceOp::Max]);
        let vals: Vec<Vec<u64>> = (0..n)
            .map(|_| (0..lanes).map(|_| rng.below(1 << 40)).collect())
            .collect();
        let mut want = vals[0].clone();
        for v in &vals[1..] {
            for (w, x) in want.iter_mut().zip(v) {
                *w = match op {
                    ReduceOp::Sum => w.wrapping_add(*x),
                    ReduceOp::Min => (*w).min(*x),
                    ReduceOp::Max => (*w).max(*x),
                };
            }
        }

        let spec = small_cluster(n)?;
        let cluster = ShoalCluster::launch(&spec).map_err(|e| format!("{e}"))?;
        let (tx, rx) = std::sync::mpsc::channel();
        for kid in 0..n {
            let mine = vals[kid as usize].clone();
            let tx = tx.clone();
            cluster.run_kernel(kid, move |mut k| {
                let ch = k.all_reduce_u64(op, &mine).unwrap();
                let got = k.collective_wait_u64(ch).unwrap();
                tx.send(got).unwrap();
            });
        }
        drop(tx);
        for _ in 0..n {
            let got = rx
                .recv_timeout(std::time::Duration::from_secs(30))
                .map_err(|_| "all-reduce result timeout".to_string())?;
            prop_assert_eq!(got, want);
        }
        cluster.join().map_err(|e| format!("{e}"))?;
        Ok(())
    });
}

#[test]
fn prop_bcast_delivers_roots_bytes_everywhere() {
    check("bcast-root-bytes", 8, |rng| {
        let n = rng.range(1, 16) as u16;
        let root = rng.below(n as u64) as u16;
        let len = rng.range(1, 512) as usize;
        let payload = rng.bytes(len);

        let spec = small_cluster(n)?;
        let cluster = ShoalCluster::launch(&spec).map_err(|e| format!("{e}"))?;
        let (tx, rx) = std::sync::mpsc::channel();
        for kid in 0..n {
            let data = if kid == root { payload.clone() } else { Vec::new() };
            let tx = tx.clone();
            cluster.run_kernel(kid, move |mut k| {
                let ch = k.bcast(root, &data).unwrap();
                let got = k.collective_wait(ch).unwrap();
                tx.send(got).unwrap();
            });
        }
        drop(tx);
        for _ in 0..n {
            let got = rx
                .recv_timeout(std::time::Duration::from_secs(30))
                .map_err(|_| "bcast result timeout".to_string())?;
            prop_assert_eq!(got, payload);
        }
        cluster.join().map_err(|e| format!("{e}"))?;
        Ok(())
    });
}

#[test]
fn prop_random_put_sequences_reach_consistent_state() {
    // Drive a 2-kernel cluster with a random sequence of puts; afterwards the
    // destination partition must equal a serially-applied model.
    check("put-sequence-consistency", 25, |rng| {
        use shoal::config::ClusterSpec;
        use shoal::prelude::*;

        let spec = ClusterSpec::single_node("p", 2);
        let cluster = ShoalCluster::launch(&spec).map_err(|e| format!("{e}"))?;
        let mut model = vec![0u8; 1 << 16];
        let ops: Vec<(u64, Vec<u8>)> = (0..rng.range(1, 24))
            .map(|_| {
                let len = rng.range(1, 512) as usize;
                let off = rng.below((1 << 16) - len as u64);
                (off, rng.bytes(len))
            })
            .collect();
        for (off, data) in &ops {
            model[*off as usize..*off as usize + data.len()].copy_from_slice(data);
        }
        let ops2 = ops.clone();
        cluster.run_kernel(0, move |mut k| {
            let mut handles = Vec::new();
            for (off, data) in &ops2 {
                handles.push(k.am_long(1, handlers::NOP, &[], data, *off).unwrap());
            }
            k.wait_all(&handles).unwrap();
            k.barrier().unwrap();
        });
        let (tx, rx) = std::sync::mpsc::channel();
        cluster.run_kernel(1, move |mut k| {
            k.barrier().unwrap();
            tx.send(k.mem().read(0, 1 << 16).unwrap()).unwrap();
        });
        let got = rx
            .recv_timeout(std::time::Duration::from_secs(30))
            .map_err(|_| "timeout".to_string())?;
        cluster.join().map_err(|e| format!("{e}"))?;
        prop_assert!(got == model, "partition state diverged from serial model");
        Ok(())
    });
}

/// The ARQ state machine delivers every payload exactly once, in order,
/// under random drop/duplicate/reorder schedules — and the sender's
/// in-flight count never exceeds the configured window. Drives two pure
/// [`ArqCore`]s through a simulated two-way lossy channel on virtual time
/// (the core is handed explicit timestamps, so the schedule is fully
/// deterministic per seed).
#[test]
fn prop_arq_delivers_exactly_once_in_order_under_loss() {
    use shoal::galapagos::transport::arq::{ArqConfig, ArqCore, Emission};
    use std::time::{Duration, Instant};

    check("arq-exactly-once", 40, |rng| {
        let window = rng.range(2, 8) as usize;
        let total = rng.range(10, 60) as usize;
        let p_drop = rng.f64() * 0.3;
        let p_dup = rng.f64() * 0.2;
        let cfg = |node_id| ArqConfig {
            node_id,
            window,
            // Generous: the property asserts delivery, not give-up.
            max_retries: 40,
            ack_interval: Duration::from_millis(2),
        };
        let mut a = ArqCore::new(cfg(0));
        let mut b = ArqCore::new(cfg(1));
        let base = Instant::now();

        // In-flight network datagrams: (deliver_at_ms, to_b, bytes).
        let mut net: Vec<(u64, bool, Vec<u8>)> = Vec::new();
        let mut delivered: Vec<Vec<u8>> = Vec::new();
        let mut sent = 0usize;
        let lossy_until = 3_000u64; // after this, the channel is clean

        // One perturbed hop: maybe drop, maybe duplicate, random delay.
        let mut push =
            |net: &mut Vec<(u64, bool, Vec<u8>)>, rng: &mut Rng, ms: u64, to_b: bool, e: Emission| {
                let lossy = ms < lossy_until;
                if lossy && rng.chance(p_drop) {
                    return;
                }
                let copies = if lossy && rng.chance(p_dup) { 2 } else { 1 };
                for _ in 0..copies {
                    let delay = 1 + rng.below(5);
                    net.push((ms + delay, to_b, e.dgram.clone()));
                }
            };

        let mut ms = 0u64;
        while delivered.len() < total && ms < 60_000 {
            ms += 1;
            let now = base + Duration::from_millis(ms);

            // A feeds new payloads while the window allows.
            if sent < total {
                if let Some(e) = a.try_send(1, &(sent as u64).to_le_bytes(), now) {
                    sent += 1;
                    push(&mut net, rng, ms, true, e);
                }
            }
            prop_assert!(
                a.inflight(1) <= window,
                "window exceeded: {} > {window}",
                a.inflight(1)
            );

            // Deliver due datagrams (random delays reorder them).
            let due: Vec<(u64, bool, Vec<u8>)> = {
                let mut d = Vec::new();
                net.retain(|(at, to_b, bytes)| {
                    if *at <= ms {
                        d.push((*at, *to_b, bytes.clone()));
                        false
                    } else {
                        true
                    }
                });
                d
            };
            for (_, to_b, bytes) in due {
                if to_b {
                    let r = b.on_datagram(&bytes, now);
                    delivered.extend(r.payloads);
                    for e in r.emit {
                        push(&mut net, rng, ms, false, e);
                    }
                } else {
                    let r = a.on_datagram(&bytes, now);
                    for e in r.emit {
                        push(&mut net, rng, ms, true, e);
                    }
                }
            }

            // Timers on both ends (retransmits, delayed ACKs).
            for (core, to_b) in [(&mut a, true), (&mut b, false)] {
                let p = core.poll(now);
                prop_assert!(
                    p.failures.is_empty(),
                    "retries exhausted under a recovering channel"
                );
                for e in p.emit {
                    push(&mut net, rng, ms, to_b, e);
                }
            }
        }

        prop_assert_eq!(delivered.len(), total);
        for (i, payload) in delivered.iter().enumerate() {
            prop_assert!(
                payload == &(i as u64).to_le_bytes().to_vec(),
                "payload {i} out of order or duplicated: {payload:?}"
            );
        }
        prop_assert!(!a.has_inflight() || sent == total, "sender stalled");
        Ok(())
    });
}

/// The failure detector's state machine on virtual time: random interleaved
/// evidence (ingress touches, soft suspicion, hard death reports, timer
/// ticks) must keep every invariant the fencing layers lean on — `Dead` is
/// sticky, the membership epoch is monotone and counts deaths exactly,
/// each death fires the sink exactly once with a unique dense epoch, tick
/// reports each death exactly once, and the timer bound is never larger
/// than one heartbeat interval while anyone is still undead.
#[test]
fn prop_peer_health_state_machine_invariants() {
    use shoal::galapagos::health::{HealthConfig, PeerHealth, PeerState};
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::{Arc as StdArc, Mutex as StdMutex};
    use std::time::Duration;

    check("peer-health", 300, |rng| {
        let peers: Vec<u16> = (1..=rng.range(1, 6) as u16).collect();
        let hb = rng.range(5, 50);
        let suspect_ms = hb + rng.range(1, 100);
        let dead_ms = suspect_ms + rng.range(1, 200);
        let h = PeerHealth::new(
            0,
            &peers,
            HealthConfig {
                heartbeat_interval: Duration::from_millis(hb),
                suspect_after: Duration::from_millis(suspect_ms),
                dead_after: Duration::from_millis(dead_ms),
            },
        );
        let sink_calls = StdArc::new(AtomicU64::new(0));
        let sink_epochs = StdArc::new(StdMutex::new(Vec::<u64>::new()));
        {
            let (calls, epochs) = (StdArc::clone(&sink_calls), StdArc::clone(&sink_epochs));
            h.set_death_sink(StdArc::new(move |_node, epoch, _detail| {
                calls.fetch_add(1, Ordering::Relaxed);
                epochs.lock().unwrap().push(epoch);
            }));
        }

        let mut now = 0u64;
        let mut tick_deaths = Vec::new();
        let mut epoch_seen = 0u64;
        for _ in 0..200 {
            now += rng.below(dead_ms + 50);
            match rng.below(4) {
                0 => h.touch(*rng.pick(&peers), now),
                1 => h.suspect(*rng.pick(&peers), "prop: soft evidence"),
                2 => {
                    if rng.chance(0.2) {
                        let _ = h.peer_dead(*rng.pick(&peers), "prop: hard evidence");
                    }
                }
                _ => tick_deaths.extend(h.tick(&peers, now)),
            }
            let _ = h.due_heartbeats(&peers, now);

            let epoch = h.membership_epoch();
            prop_assert!(epoch >= epoch_seen, "membership epoch went backwards");
            epoch_seen = epoch;
            // The epoch counts deaths exactly, and the sink fired once per.
            prop_assert_eq!(epoch, h.dead_count());
            prop_assert_eq!(sink_calls.load(Ordering::Relaxed), h.dead_count());
            for &p in &peers {
                if h.is_dead(p) {
                    h.touch(p, now);
                    prop_assert!(h.is_dead(p), "Dead must be sticky under touch");
                    let de = h.died_epoch(p);
                    prop_assert!(de >= 1 && de <= epoch, "death epoch stamp out of range");
                } else {
                    prop_assert_eq!(h.died_epoch(p), 0);
                }
            }
            if h.dead_count() < peers.len() as u64 {
                let d = h
                    .next_deadline(&peers, now)
                    .ok_or("undead peers but no deadline")?;
                prop_assert!(
                    d <= Duration::from_millis(hb),
                    "timer bound {d:?} exceeds the heartbeat interval"
                );
            }
        }

        // Terminal silence: one long-enough gap kills every survivor.
        now += dead_ms;
        tick_deaths.extend(h.tick(&peers, now));
        prop_assert_eq!(h.dead_count(), peers.len() as u64);
        prop_assert!(h.next_deadline(&peers, now).is_none(), "all dead: no deadline");
        prop_assert!(h.tick(&peers, now + dead_ms).is_empty(), "dead peers re-reported");
        prop_assert!(
            h.due_heartbeats(&peers, now + dead_ms).is_empty(),
            "heartbeats to dead peers"
        );
        for &p in &peers {
            prop_assert_eq!(h.state(p), PeerState::Dead);
        }

        // tick() never reports the same death twice, and every death —
        // timed or hard-evidence — delivered the sink a unique dense epoch.
        let reported = tick_deaths.len();
        tick_deaths.sort_unstable();
        tick_deaths.dedup();
        prop_assert_eq!(tick_deaths.len(), reported);
        let mut epochs = sink_epochs.lock().unwrap().clone();
        epochs.sort_unstable();
        prop_assert_eq!(epochs, (1..=peers.len() as u64).collect::<Vec<_>>());
        prop_assert_eq!(sink_calls.load(Ordering::Relaxed), peers.len() as u64);
        Ok(())
    });
}
