//! PJRT runtime integration: AOT artifacts load, execute, and agree with the
//! rust serial oracle (the same contract python/tests checks against ref.py).

use shoal::apps::jacobi::compute::{JacobiCompute, RustSweep, XlaSweep};
use shoal::runtime::Engine;
use shoal::util::rng::Rng;

fn engine() -> std::sync::Arc<Engine> {
    Engine::load_default().expect("run `make artifacts` first")
}

#[test]
fn manifest_lists_expected_artifacts() {
    let e = engine();
    let shapes = e.jacobi_shapes();
    assert!(shapes.contains(&(16, 34)), "{shapes:?}");
    assert!(shapes.contains(&(512, 4098)), "{shapes:?}");
    assert!(shapes.len() >= 9);
}

#[test]
fn every_artifact_shape_matches_oracle() {
    let e = engine();
    let xla = XlaSweep::new(std::sync::Arc::clone(&e));
    let mut rng = Rng::new(42);
    for (rows, cols) in e.jacobi_shapes() {
        if rows * cols > 200_000 {
            continue; // keep the test fast; big shapes covered by benches
        }
        let padded: Vec<f32> =
            (0..(rows + 2) * cols).map(|_| rng.f32_range(-10.0, 10.0)).collect();
        let got = xla.step(rows, cols, &padded).unwrap();
        let want = RustSweep.step(rows, cols, &padded).unwrap();
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-3, "{rows}×{cols} idx {i}: {g} vs {w}");
        }
    }
}

#[test]
fn repeated_execution_is_deterministic() {
    let e = engine();
    let padded: Vec<f32> = (0..18 * 34).map(|i| (i % 23) as f32 * 0.5).collect();
    let a = e.jacobi_step(16, 34, &padded).unwrap();
    let b = e.jacobi_step(16, 34, &padded).unwrap();
    assert_eq!(a, b);
}

#[test]
fn iteration_converges_like_oracle() {
    // Run 50 iterations through the engine on a single padded tile with
    // fixed halos, against the serial sweep.
    let e = engine();
    let (rows, cols) = (16, 34);
    let mut rng = Rng::new(7);
    let mut tile: Vec<f32> = (0..rows * cols).map(|_| rng.f32_range(0.0, 1.0)).collect();
    let halo_top = vec![1.0f32; cols];
    let halo_bot = vec![0.0f32; cols];
    let mut tile_oracle = tile.clone();

    for _ in 0..50 {
        let mut padded = Vec::with_capacity((rows + 2) * cols);
        padded.extend_from_slice(&halo_top);
        padded.extend_from_slice(&tile);
        padded.extend_from_slice(&halo_bot);
        tile = e.jacobi_step(rows, cols, &padded).unwrap();

        let mut padded_o = Vec::with_capacity((rows + 2) * cols);
        padded_o.extend_from_slice(&halo_top);
        padded_o.extend_from_slice(&tile_oracle);
        padded_o.extend_from_slice(&halo_bot);
        tile_oracle = RustSweep.step(rows, cols, &padded_o).unwrap();
    }
    for (g, w) in tile.iter().zip(&tile_oracle) {
        assert!((g - w).abs() < 1e-3, "{g} vs {w}");
    }
    // Physically sensible: interior between the halo temperatures.
    assert!(tile.iter().skip(cols).take(cols).all(|&v| (0.0..=1.0).contains(&v)));
}

#[test]
fn engine_shared_across_threads() {
    let e = engine();
    e.warm("jacobi_r16_c34").unwrap();
    let mut handles = Vec::new();
    for t in 0..8 {
        let e = std::sync::Arc::clone(&e);
        handles.push(std::thread::spawn(move || {
            let padded: Vec<f32> = (0..18 * 34).map(|i| ((i + t) % 17) as f32).collect();
            for _ in 0..10 {
                let out = e.jacobi_step(16, 34, &padded).unwrap();
                assert_eq!(out.len(), 16 * 34);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(
        e.stats().compiles.load(std::sync::atomic::Ordering::Relaxed),
        1,
        "executable must compile exactly once"
    );
}

#[test]
fn missing_artifact_error_is_actionable() {
    let e = engine();
    let err = e.jacobi_step(5, 7, &vec![0.0; 49]).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("aot.py"), "{msg}");
}
