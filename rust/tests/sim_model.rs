//! Cross-checks between the DES cost model and the *real* library: the
//! model's qualitative orderings must also hold for measured wall-clock
//! numbers wherever both exist on this machine.

use shoal::bench::micro::{measure_latency, measure_throughput, BenchPlacement};
use shoal::config::TransportKind;
use shoal::sim::{CostModel, MsgKind, Protocol, Topology};

#[test]
fn measured_tcp_slower_than_in_proc() {
    // Model: SW-SW(diff) > SW-SW(same). Measured must agree.
    let in_proc = measure_latency(BenchPlacement::sw_same(), MsgKind::MediumFifo, 64, 100, 20)
        .unwrap();
    let tcp = measure_latency(
        BenchPlacement::sw_diff(TransportKind::Tcp),
        MsgKind::MediumFifo,
        64,
        100,
        20,
    )
    .unwrap();
    assert!(
        tcp.median() > in_proc.median(),
        "tcp {} vs in-proc {}",
        tcp.median(),
        in_proc.median()
    );
    let cm = CostModel::paper();
    let m_same = cm.latency_ns(Topology::SwSwSame, Protocol::Tcp, MsgKind::MediumFifo, 64).unwrap();
    let m_diff = cm.latency_ns(Topology::SwSwDiff, Protocol::Tcp, MsgKind::MediumFifo, 64).unwrap();
    assert!(m_diff > m_same);
}

#[test]
fn measured_throughput_rises_with_payload() {
    // Model: throughput increases with payload. Measured must agree.
    let small = measure_throughput(BenchPlacement::sw_same(), MsgKind::LongFifo, 64, 400).unwrap();
    let large = measure_throughput(BenchPlacement::sw_same(), MsgKind::LongFifo, 4096, 400).unwrap();
    assert!(large > small * 2.0, "small {small} large {large}");
}

#[test]
fn measured_latency_distribution_sane() {
    let s = measure_latency(BenchPlacement::sw_same(), MsgKind::LongFifo, 1024, 300, 50).unwrap();
    assert!(s.min() > 0.0);
    assert!(s.median() >= s.percentile(0.25));
    assert!(s.p99() >= s.median());
    // Round trips through two thread hops can't be faster than ~1 µs.
    assert!(s.median() > 1_000.0, "median {}", s.median());
}

#[test]
fn model_udp_gap_matches_real_udp_core_behavior() {
    // The cost model refuses UDP+HW beyond the MTU; the transport layer
    // enforces the same rule (tested in failure_injection); here: the model
    // boundary is exactly the MTU crossing.
    let cm = CostModel::paper();
    let at = |p| cm.latency_ns(Topology::HwHwDiff, Protocol::Udp, MsgKind::MediumFifo, p);
    assert!(at(1024).is_some());
    assert!(at(2048).is_none());
    // The exact boundary: payload + headers vs 1472.
    let mut lo = 1024usize;
    let mut hi = 2048usize;
    while hi - lo > 1 {
        let mid = (lo + hi) / 2;
        if at(mid).is_some() {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    // Boundary within a header's width of the MTU.
    assert!((1380..=1472).contains(&lo), "boundary at {lo}");
}

#[test]
fn gascore_cycle_stats_feed_model_scale() {
    // A functional HW run produces cycle counts in the ballpark the latency
    // model assumes (hundreds of ns to µs per message, not ms).
    use shoal::config::{ClusterBuilder, Platform};
    use shoal::prelude::*;

    let mut b = ClusterBuilder::new();
    let n0 = b.node("cpu", Platform::Sw);
    let n1 = b.node("fpga", Platform::Hw);
    let k0 = b.kernel(n0);
    let k1 = b.kernel(n1);
    let spec = b.build().unwrap();
    let cluster = ShoalCluster::launch(&spec).unwrap();
    cluster.run_kernel(k0, move |mut k| {
        let handles: Vec<AmHandle> = (0..100)
            .map(|_| k.am_long(k1, handlers::NOP, &[], &[7; 1024], 0).unwrap())
            .collect();
        k.wait_all(&handles).unwrap();
        k.barrier().unwrap();
    });
    cluster.run_kernel(k1, move |mut k| {
        k.barrier().unwrap();
    });
    let stats = cluster.gascore_stats(n1).unwrap();
    cluster.join().unwrap();
    let msgs = stats.messages_in.load(std::sync::atomic::Ordering::Relaxed);
    assert!(msgs >= 100, "gascore saw {msgs} messages");
    let per_msg_ns = stats.modeled_ns() / msgs as f64;
    assert!(
        (100.0..20_000.0).contains(&per_msg_ns),
        "modeled {per_msg_ns} ns/message out of expected band"
    );
}
