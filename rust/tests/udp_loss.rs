//! The UDP loss-injection battery: with `SHOAL_UDP_DROP` forcing ≥5%
//! datagram loss, the ARQ layer must make UDP-hosted workloads complete
//! with results identical to TCP — the scenario the paper never reached
//! (its hardware UDP core "simply accepts loss", §IV-B1, so its UDP
//! evaluation stops at microbenchmarks).
//!
//! The tests mutate process environment variables, so the whole battery is
//! serialized through `ENV_LOCK` (concurrent `setenv`/`getenv` is UB on
//! glibc); CI also runs this binary with the drop rate exported (belt and
//! braces — the tests force it themselves, under the lock).

use std::io::Write;
use std::process::{Child, Command, Stdio};

use shoal::config::parse::parse_cluster;
use shoal::config::{ClusterBuilder, Platform, TransportKind};
use shoal::prelude::*;
use shoal::shoal_node::cluster::ShoalCluster;

/// The battery's drop rate (per outgoing datagram, each direction).
const DROP: &str = "0.08";

/// Serializes the whole battery: every test here mutates process
/// environment variables (`SHOAL_UDP_DROP`, `SHOAL_TRANSPORT`), and
/// `setenv` concurrent with `getenv` from another test thread is undefined
/// behavior on glibc. One test at a time, env writes only under the guard.
static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn battery_guard() -> std::sync::MutexGuard<'static, ()> {
    let g = ENV_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    std::env::set_var("SHOAL_UDP_DROP", DROP);
    g
}

/// Guard serializing port allocation + binding across parallel tests.
static PORT_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn free_ports() -> (u16, u16) {
    let a = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let b = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    (a.local_addr().unwrap().port(), b.local_addr().unwrap().port())
}

fn cluster_file(transport: &str, p0: u16, p1: u16) -> String {
    format!(
        r#"
transport = "{transport}"
udp_window = 16
udp_retries = 8

[[node]]
name = "driver"
platform = "sw"
address = "127.0.0.1:{p0}"

[[node]]
name = "server"
platform = "sw"
address = "127.0.0.1:{p1}"

[[kernel]]
node = "driver"

[[kernel]]
node = "server"
count = 2
"#
    )
}

fn spawn_server(path: &std::path::Path, node: u16) -> Child {
    spawn_server_with(path, node, &[])
}

fn spawn_server_with(path: &std::path::Path, node: u16, envs: &[(&str, &str)]) -> Child {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_shoal"));
    cmd.args([
        "serve",
        "--cluster",
        path.to_str().unwrap(),
        "--node",
        &node.to_string(),
        "--app",
        "allreduce",
    ])
    .env("SHOAL_UDP_DROP", DROP);
    for (k, v) in envs {
        cmd.env(k, v);
    }
    cmd.stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn shoal serve")
}

/// Cross-process all-reduce over UDP with ≥5% injected loss on both ends:
/// the fold must still complete and equal the TCP (loss-free) reference —
/// the acceptance scenario of the reliability layer.
#[test]
fn multiprocess_all_reduce_over_lossy_udp_matches_tcp() {
    let _battery = battery_guard();
    let mut results = Vec::new();
    for transport in ["tcp", "udp"] {
        let _guard = PORT_LOCK.lock().unwrap();
        let (p0, p1) = free_ports();
        let text = cluster_file(transport, p0, p1);
        let spec = parse_cluster(&text).unwrap();

        let dir = std::env::temp_dir().join(format!("shoal-loss-{transport}-{p0}-{p1}"));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cluster.toml");
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(text.as_bytes()).unwrap();
        drop(f);

        let mut server = spawn_server(&path, 1);
        let cluster = ShoalCluster::launch_node(&spec, 0).unwrap();
        let (tx, rx) = std::sync::mpsc::channel();
        cluster.run_kernel(0, move |mut k| {
            // Readiness handshake (hellos are ASYNC mediums — resent until
            // released, so they need no reliability to bootstrap it).
            let mut seen = std::collections::HashSet::new();
            while seen.len() < 2 {
                seen.insert(k.recv_medium().unwrap().src);
            }
            for kid in [1u16, 2] {
                let _ = k.am_medium_async(kid, handlers::NOP, &[], b"go").unwrap();
            }
            let ch = k.all_reduce_u64(ReduceOp::Sum, &[k.id() as u64]).unwrap();
            let v = k.collective_wait_u64(ch).unwrap();
            tx.send(v).unwrap();
        });
        let v = rx
            .recv_timeout(std::time::Duration::from_secs(120))
            .unwrap_or_else(|_| panic!("all-reduce over lossy {transport} timed out"));
        cluster.join().unwrap();
        let status = server.wait().expect("server exits after the collective");
        assert!(status.success(), "server exit over {transport}: {status:?}");
        std::fs::remove_dir_all(&dir).ok();
        results.push(v);
    }
    assert_eq!(results[0], results[1], "lossy-UDP fold differs from the TCP reference");
    assert_eq!(results[0], vec![3], "kernel ids 0+1+2");
}

/// Jacobi with a convergence tolerance over lossy UDP: the solver's halo
/// puts, barriers and residual all-reduces all ride the reliable datapath,
/// and the final grid must be bitwise identical to the TCP run.
#[test]
fn jacobi_with_tolerance_over_lossy_udp_matches_tcp() {
    let _battery = battery_guard();
    let cfg = shoal::apps::jacobi::JacobiConfig {
        n: 34,
        iters: 24,
        workers: 2,
        nodes: 2,
        hw: false,
        chunked: false,
        tolerance: Some(0.02),
        check_every: 4,
    };
    let run_with = |transport: &str| {
        std::env::set_var("SHOAL_TRANSPORT", transport);
        let r = shoal::apps::jacobi::run(&cfg).unwrap_or_else(|e| {
            panic!("jacobi over lossy {transport} failed: {e}")
        });
        std::env::remove_var("SHOAL_TRANSPORT");
        r
    };
    let tcp = run_with("tcp");
    let udp = run_with("udp");
    assert_eq!(tcp.iters_done, udp.iters_done, "convergence sweep count diverged");
    assert_eq!(tcp.converged, udp.converged);
    assert_eq!(tcp.grid, udp.grid, "lossy-UDP grid differs from the TCP reference");
}

/// Sharded reactors under loss: with `router_shards = 4`, destination-hashed
/// egress ownership keeps each (source, peer) flow on exactly one ARQ
/// endpoint pair — so delivery must stay exactly-once and in-order *per
/// peer* even while four reactor threads drive disjoint windows over the
/// same lossy socket.
#[test]
fn sharded_routers_preserve_per_peer_ordering_over_lossy_udp() {
    let _battery = battery_guard();
    let mut b = ClusterBuilder::new();
    b.transport(TransportKind::Udp);
    b.udp_window(8).udp_retries(10);
    b.router_shards(4);
    let n0 = b.node_at("hub", Platform::Sw, "127.0.0.1:0");
    let k0 = b.kernel(n0);
    let mut peer_kernels = Vec::new();
    for i in 1..=2u16 {
        let n = b.node_at(&format!("peer{i}"), Platform::Sw, "127.0.0.1:0");
        peer_kernels.push(b.kernel(n));
    }
    let spec = b.build().unwrap();
    let cluster = ShoalCluster::launch(&spec).unwrap();

    const PER_PEER: u64 = 48;
    let dests = peer_kernels.clone();
    cluster.run_kernel(k0, move |mut k| {
        let mut handles = Vec::new();
        for seq in 0..PER_PEER {
            for &dst in &dests {
                handles.push(k.am_medium(dst, handlers::NOP, &[], &seq.to_le_bytes()).unwrap());
            }
        }
        k.wait_all(&handles).unwrap();
    });
    let (tx, rx) = std::sync::mpsc::channel();
    for &kid in &peer_kernels {
        let tx = tx.clone();
        cluster.run_kernel(kid, move |k| {
            for want in 0..PER_PEER {
                let m = k.recv_medium().unwrap();
                let got = u64::from_le_bytes(m.payload.as_slice().try_into().unwrap());
                assert_eq!(got, want, "kernel {kid}: medium out of order or duplicated");
            }
            tx.send(kid).unwrap();
        });
    }
    drop(tx);
    let mut done = std::collections::HashSet::new();
    while done.len() < peer_kernels.len() {
        done.insert(
            rx.recv_timeout(std::time::Duration::from_secs(120)).expect("peer finished"),
        );
    }
    cluster.join().unwrap();
}

/// The multiprocess acceptance run with four reactors per node:
/// `SHOAL_ROUTER_SHARDS=4` exported to both processes must leave the lossy
/// all-reduce result identical to the single-router reference.
#[test]
fn multiprocess_all_reduce_over_lossy_udp_with_sharded_routers() {
    let _battery = battery_guard();
    std::env::set_var("SHOAL_ROUTER_SHARDS", "4");
    let _guard = PORT_LOCK.lock().unwrap();
    let (p0, p1) = free_ports();
    let text = cluster_file("udp", p0, p1);
    let spec = parse_cluster(&text).unwrap();

    let dir = std::env::temp_dir().join(format!("shoal-loss-shards-{p0}-{p1}"));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cluster.toml");
    let mut f = std::fs::File::create(&path).unwrap();
    f.write_all(text.as_bytes()).unwrap();
    drop(f);

    let mut server = spawn_server_with(&path, 1, &[("SHOAL_ROUTER_SHARDS", "4")]);
    let cluster = ShoalCluster::launch_node(&spec, 0).unwrap();
    let (tx, rx) = std::sync::mpsc::channel();
    cluster.run_kernel(0, move |mut k| {
        let mut seen = std::collections::HashSet::new();
        while seen.len() < 2 {
            seen.insert(k.recv_medium().unwrap().src);
        }
        for kid in [1u16, 2] {
            let _ = k.am_medium_async(kid, handlers::NOP, &[], b"go").unwrap();
        }
        let ch = k.all_reduce_u64(ReduceOp::Sum, &[k.id() as u64]).unwrap();
        let v = k.collective_wait_u64(ch).unwrap();
        tx.send(v).unwrap();
    });
    let v = rx
        .recv_timeout(std::time::Duration::from_secs(120))
        .expect("sharded all-reduce over lossy udp timed out");
    cluster.join().unwrap();
    std::env::remove_var("SHOAL_ROUTER_SHARDS");
    let status = server.wait().expect("server exits after the collective");
    assert!(status.success(), "sharded server exit: {status:?}");
    std::fs::remove_dir_all(&dir).ok();
    assert_eq!(v, vec![3], "kernel ids 0+1+2");
}

/// A simulated-hardware node behind a lossy UDP link: the GAScore must see
/// every AM exactly once (the ARQ dedup/reorder runs underneath its
/// "From Network" interface), proven by an exact ingress message count.
#[test]
fn hw_node_over_lossy_udp_sees_every_am_exactly_once() {
    let _battery = battery_guard();
    let mut b = ClusterBuilder::new();
    b.transport(TransportKind::Udp);
    b.default_segment(1 << 20);
    b.udp_window(8).udp_retries(10);
    let n0 = b.node_at("driver", Platform::Sw, "127.0.0.1:0");
    let n1 = b.node_at("fpga", Platform::Hw, "127.0.0.1:0");
    let k0 = b.kernel(n0);
    let k1 = b.kernel(n1);
    let spec = b.build().unwrap();
    let cluster = ShoalCluster::launch(&spec).unwrap();

    const PUTS: u64 = 40;
    const GETS: u64 = 10;
    let (tx, rx) = std::sync::mpsc::channel();
    cluster.run_kernel(k0, move |mut k| {
        let mut handles = Vec::new();
        for i in 0..PUTS {
            let payload = vec![(i % 251) as u8; 64];
            handles.push(k.am_long(k1, handlers::NOP, &[], &payload, i * 64).unwrap());
        }
        k.wait_all(&handles).unwrap();
        // Read a few rows back and verify the data survived the loss.
        for i in 0..GETS {
            let h = k.am_long_get(k1, handlers::NOP, i * 64, 64, i * 64).unwrap();
            k.wait(h).unwrap();
            assert_eq!(k.mem().read(i * 64, 64).unwrap(), vec![(i % 251) as u8; 64]);
        }
        tx.send(()).unwrap();
    });
    rx.recv_timeout(std::time::Duration::from_secs(120)).expect("driver finished");
    let stats = cluster.gascore_stats(n1).expect("hw node has a gascore");
    let seen = stats.messages_in.load(std::sync::atomic::Ordering::Relaxed);
    cluster.join().unwrap();
    assert_eq!(
        seen,
        PUTS + GETS,
        "GAScore must ingest each AM exactly once despite {DROP} datagram loss"
    );
}
