//! Minimal logging facade, API-compatible with the subset of the crates.io
//! `log` crate this workspace uses (`error!` … `trace!` with format args).
//!
//! The build environment is fully offline, so instead of the real facade the
//! workspace vendors this stand-in: records go straight to stderr, filtered
//! by the `RUST_LOG` environment variable (`error`, `warn`, `info`, `debug`,
//! `trace`, or `off`; default `warn`). There is no logger registry — the
//! hot-path cost of a disabled level is one atomic load.

use std::sync::atomic::{AtomicU8, Ordering};

/// Numeric severity, ascending verbosity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

/// 0 = not yet initialized from the environment.
static MAX_LEVEL: AtomicU8 = AtomicU8::new(0);

fn init_from_env() -> u8 {
    let lvl = match std::env::var("RUST_LOG").ok().as_deref() {
        Some("off") | Some("none") => 0xFF, // sentinel: everything disabled
        Some("error") => Level::Error as u8,
        Some("info") => Level::Info as u8,
        Some("debug") => Level::Debug as u8,
        Some("trace") => Level::Trace as u8,
        // `warn`, unset, or unrecognized: warnings and errors only.
        _ => Level::Warn as u8,
    };
    MAX_LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// True when records at `level` should be emitted.
pub fn enabled(level: Level) -> bool {
    let mut max = MAX_LEVEL.load(Ordering::Relaxed);
    if max == 0 {
        max = init_from_env();
    }
    max != 0xFF && (level as u8) <= max
}

/// Emit one record (used by the macros; not called directly).
pub fn __emit(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{}] {}", level.as_str(), args);
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::__emit($crate::Level::Error, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::__emit($crate::Level::Warn, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::__emit($crate::Level::Info, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::__emit($crate::Level::Debug, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::__emit($crate::Level::Trace, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Trace);
        assert_eq!(Level::Warn.as_str(), "WARN");
    }

    #[test]
    fn macros_expand() {
        // Smoke: all five levels format without panicking.
        error!("e {}", 1);
        warn!("w {}", 2);
        info!("i {}", 3);
        debug!("d {}", 4);
        trace!("t {}", 5);
    }
}
